"""Quickstart: the paper's W4A16 GEMM via the plan-based API, then a
quantized layer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.quant import quantize, dequantize
from repro.kernels import planning

key = jax.random.PRNGKey(0)

# 1. Quantize an FP weight matrix to INT4 with group-wise scales (Eq. 1).
K, N = 4096, 1024                         # K >> N: the LLM-decode regime
w = jax.random.normal(key, (K, N), jnp.float32)
qt = quantize(w, group_size=128)
print(f"weight: {w.nbytes/1e6:.1f} MB fp32 -> {qt.nbytes_packed()/1e6:.1f} MB "
      f"packed int4 (+scales)")

# 2. The primary path: describe the problem, plan it, execute the plan.
x = jax.random.normal(key, (4, K), jnp.float32)     # small M, like decoding
problem = planning.MatmulProblem.from_operands(x, qt)
plan = planning.plan_matmul(problem)                # cost-model planner
y = planning.execute(plan, x, qt)
err = float(jnp.abs(y - x @ dequantize(qt)).max())
print(f"planned: {plan.strategy} split_k={plan.split_k} "
      f"out={y.shape} max|err|={err:.2e}")

# 3. Any registered strategy can be forced — same execute, no dispatcher.
for strategy in planning.available_strategies():
    p = planning.plan_matmul(problem, strategy=strategy)
    y = planning.execute(p, x, qt, interpret=True)
    err = float(jnp.abs(y - x @ dequantize(qt)).max())
    print(f"  strategy={strategy:10s} out={y.shape} max|err|={err:.2e}")

# 4. Decisions are memoized process-wide and persist to JSON.
assert planning.plan_matmul(problem) == plan        # cache hit
n = planning.save_plan_cache("/tmp/repro_quickstart_plans.json")
print(f"plan cache: {n} plan(s) persisted "
      f"({planning.PLAN_CACHE.hits} hits / {planning.PLAN_CACHE.misses} "
      f"misses); split_k for (M=4, N={N}, K={K}):",
      planning.choose_split_k(4, N, K))

# 5. A quantized model layer end-to-end (linear() plans internally).
from repro.models import layers

p = layers.init_linear(key, K, N, jnp.float32)
p["kernel"] = quantize(p["kernel"], group_size=128)
y = layers.linear(p, x)
print("quantized Linear:", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))
