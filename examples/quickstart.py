"""Quickstart: the paper's W4A16 GEMM in five lines, then a quantized layer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.quant import quantize, dequantize
from repro.kernels import ops

key = jax.random.PRNGKey(0)

# 1. Quantize an FP weight matrix to INT4 with group-wise scales (Eq. 1).
K, N = 4096, 1024                         # K >> N: the LLM-decode regime
w = jax.random.normal(key, (K, N), jnp.float32)
qt = quantize(w, group_size=128)
print(f"weight: {w.nbytes/1e6:.1f} MB fp32 -> {qt.nbytes_packed()/1e6:.1f} MB "
      f"packed int4 (+scales)")

# 2. W4A16 matmul: C = A · Dequant(W) (Eq. 2), with strategy dispatch.
x = jax.random.normal(key, (4, K), jnp.float32)     # small M, like decoding
for strategy in ("reference", "xla", "fused", "decoupled"):
    y = ops.w4a16_matmul(x, qt, strategy=strategy)
    err = float(jnp.abs(y - x @ dequantize(qt)).max())
    print(f"  strategy={strategy:10s} out={y.shape} max|err|={err:.2e}")

# 3. The Split-K heuristic picks a split for deep-K decode GEMMs.
print("chosen split_k for (M=4, N=1024, K=4096):",
      ops.choose_split_k(4, N, K))

# 4. A quantized model layer end-to-end.
from repro.models import layers

p = layers.init_linear(key, K, N, jnp.float32)
p["kernel"] = quantize(p["kernel"], group_size=128)
y = layers.linear(p, x)
print("quantized Linear:", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))
