"""Quickstart: the paper's W4A16 GEMM via the plan-based API, then a
quantized layer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.quant import quantize, dequantize
from repro.kernels import planning

key = jax.random.PRNGKey(0)

# 1. Quantize an FP weight matrix to INT4 with group-wise scales (Eq. 1).
K, N = 4096, 1024                         # K >> N: the LLM-decode regime
w = jax.random.normal(key, (K, N), jnp.float32)
qt = quantize(w, group_size=128)
print(f"weight: {w.nbytes/1e6:.1f} MB fp32 -> {qt.nbytes_packed()/1e6:.1f} MB "
      f"packed int4 (+scales)")

# 2. The primary path: describe the problem, plan it, execute the plan.
x = jax.random.normal(key, (4, K), jnp.float32)     # small M, like decoding
problem = planning.MatmulProblem.from_operands(x, qt)
plan = planning.plan_matmul(problem)                # cost-model planner
y = planning.execute(plan, x, qt)
err = float(jnp.abs(y - x @ dequantize(qt)).max())
print(f"planned: {plan.strategy} split_k={plan.split_k} "
      f"out={y.shape} max|err|={err:.2e}")

# 3. Any strategy supporting the tensor's QuantFormat can be forced —
#    same execute, no dispatcher (format-incompatible ones are refused).
for strategy in planning.strategies_for_format(qt.format.name):
    p = planning.plan_matmul(problem, strategy=strategy)
    y = planning.execute(p, x, qt, interpret=True)
    err = float(jnp.abs(y - x @ dequantize(qt)).max())
    print(f"  strategy={strategy:10s} out={y.shape} max|err|={err:.2e}")

# 4. Decisions are memoized process-wide and persist to JSON.
assert planning.plan_matmul(problem) == plan        # cache hit
n = planning.save_plan_cache("/tmp/repro_quickstart_plans.json")
print(f"plan cache: {n} plan(s) persisted "
      f"({planning.PLAN_CACHE.hits} hits / {planning.PLAN_CACHE.misses} "
      f"misses); split_k for (M=4, N={N}, K={K}):",
      planning.choose_split_k(4, N, K))

# 5. A quantized model layer end-to-end (linear() plans internally).
from repro.models import layers

p = layers.init_linear(key, K, N, jnp.float32)
p["kernel"] = quantize(p["kernel"], group_size=128)
y = layers.linear(p, x)
print("quantized Linear:", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))

# 6. Quantization formats are first-class and registered: the same plan →
#    execute path runs W8A16 (per-channel int8) and W4A8 (dynamic int8
#    activations, LiquidGEMM-style) — the planner only considers
#    strategies that declare support for the tensor's format.
from repro.core import quant

for fmt_name in quant.available_formats():
    qf = quantize(w, fmt_name)
    prob = planning.MatmulProblem.from_operands(x, qf)
    pf = planning.plan_matmul(prob)
    err = float(jnp.abs(planning.execute(pf, x, qf) - x @ w).max())
    print(f"  format={fmt_name:14s} bits=w{qf.format.weight_bits} "
          f"scales={tuple(qf.scales.shape)} -> {pf.strategy:9s} "
          f"max|err vs fp32|={err:.2e}")
