"""End-to-end driver: train a small LM for a few hundred steps, checkpoint,
quantize to W4A16, and compare quantized vs dense serving logits.

    PYTHONPATH=src python examples/train_w4a16.py [--steps 300]

(Defaults to 120 steps so the example finishes quickly on CPU; pass
--steps 300 for the full run. Loss should drop visibly either way.)
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.train import main as train_main
from repro.models import layers, transformer as T


def run(steps: int):
    arch = "h2o-danube-1.8b"
    losses = train_main([
        "--arch", arch, "--reduced",
        "--steps", str(steps), "--batch", "8", "--seq", "64",
        "--ckpt-dir", "/tmp/repro_quickstart_ckpt",
    ])
    assert losses[-1] < losses[0], "loss did not decrease"

    # restore the trained params and quantize for serving
    from repro.checkpoint import restore_checkpoint
    from repro.optim import AdamWConfig, adamw_init

    # pin every quantized layer to an explicit KernelPlan (the per-config
    # plan override) instead of re-planning at trace time
    from repro.kernels import planning

    cfg = configs.get_reduced(arch)
    cfg = dataclasses.replace(
        cfg, w4a16_plan=planning.KernelPlan(strategy="xla"))
    key = jax.random.PRNGKey(0)
    like = {"params": T.init_params(key, cfg),
            "opt": adamw_init(like_params := T.init_params(key, cfg),
                              AdamWConfig())}
    restored, step, _ = restore_checkpoint("/tmp/repro_quickstart_ckpt", like)
    params = restored["params"]
    print(f"[example] restored checkpoint at step {step}")

    qparams = layers.quantize_tree(params, group_size=cfg.group_size,
                                   min_size=0)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    dense = T.forward(params, cfg, toks)
    quant = T.forward(qparams, cfg, toks)
    agree = float(jnp.mean(
        (jnp.argmax(dense, -1) == jnp.argmax(quant, -1)).astype(jnp.float32)))
    print(f"[example] greedy-token agreement dense vs W4A16: {agree:.1%}")
    assert agree > 0.7


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    run(ap.parse_args().steps)
