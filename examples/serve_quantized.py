"""Serve a W4A16-quantized model with batched requests (paper's deployment).

Loads a reduced h2o-danube (SWA) model, quantizes every linear to INT4,
and runs the continuous-batching engine (runtime/engine.py): requests
arrive over time, a slot scheduler admits/evicts them per decode step, and
every decode runs the K≫N small-M GEMM regime where the paper's Split-K
strategy applies. Context lives in the paged, prefix-shared KV block pool
(--page-size / --prefill-chunk / --kv-format; --ring restores the legacy
per-slot ring caches). The planner chooses the kernel per layer ("auto");
its decisions persist to a JSON plan cache that later runs (or the train
driver) warm-start from. Add ``--mesh 2x4`` (with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) for mesh-sharded
serving with shard-local plans — see docs/serving.md.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main([
        "--arch", "h2o-danube-1.8b", "--reduced",
        "--batch", "4", "--prompt-len", "32", "--gen", "12",
        "--requests", "8", "--arrival-every", "2",
        "--strategy", "auto",
        "--format", "w4a16_g128",     # or w8a16_channel / w4a8_g128
        "--page-size", "8", "--prefill-chunk", "16",
        "--kv-format", "kv_fp16",     # or kv8_channel (per-head INT8 KV)
        "--plan-cache", "/tmp/repro_plan_cache.json",
    ])
