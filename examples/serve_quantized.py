"""Serve a W4A16-quantized model with batched requests (paper's deployment).

Loads a reduced h2o-danube (SWA) model, quantizes every linear to INT4,
prefills a batch of prompts and decodes greedily — the K≫N small-M GEMM
regime where the paper's Split-K strategy applies. The planner chooses the
kernel per layer ("auto"); its decisions persist to a JSON plan cache that
later runs (or the train driver) warm-start from.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main([
        "--arch", "h2o-danube-1.8b", "--reduced",
        "--batch", "4", "--prompt-len", "32", "--gen", "12",
        "--strategy", "auto",
        "--format", "w4a16_g128",     # or w8a16_channel / w4a8_g128
        "--plan-cache", "/tmp/repro_plan_cache.json",
    ])
