"""AdamW in pure JAX, with configurable state dtype.

``state_dtype=bfloat16`` halves optimizer memory — the distributed-
optimization trick that lets llama3-405b train_4k fit 16 GB/chip HBM on the
single-pod mesh (see EXPERIMENTS.md §Dry-run). Moments are stored in
``state_dtype`` but the update math runs in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def adamw_update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return (newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": newm, "v": newv, "count": count}
    return newp, new_state, {"grad_norm": gnorm}
