"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int = 100, total: int = 10_000,
                    floor: float = 0.1):
    """Linear warmup → cosine decay to ``floor`` × peak. Returns a scale."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
