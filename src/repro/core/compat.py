"""JAX cross-version compatibility shims.

The repo targets the current mesh-context API (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.get_abstract_mesh``) but must also run on
older jaxlibs (0.4.x) where those live under different names — or don't
exist and have to be emulated through the internal resource-env plumbing.
Everything version-sensitive funnels through here so kernels, models, and
launchers stay on one spelling.
"""
from __future__ import annotations

import contextlib

import jax


def get_abstract_mesh():
    """The ambient mesh set by :func:`set_mesh`, or None outside one — also
    None on jax builds without the AbstractMesh plumbing at all (callers
    degrade to unsharded execution, never crash)."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except AttributeError:
        try:
            from jax._src import mesh as mesh_lib

            m = mesh_lib.get_abstract_mesh()
        except Exception:
            return None
    if m is None or not getattr(m, "axis_names", ()):
        return None
    return m


@contextlib.contextmanager
def set_mesh(mesh):
    """``with set_mesh(mesh):`` — ambient-mesh context on any jax version."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    elif hasattr(jax.sharding, "use_mesh"):
        with jax.sharding.use_mesh(mesh):
            yield mesh
    else:
        # 0.4.x: enter the physical resource env (bare-PartitionSpec
        # with_sharding_constraint) AND the abstract-mesh env (shard_hint /
        # moe dispatch read it) — together these emulate jax.set_mesh.
        # Builds without even the internal abstract-mesh plumbing get the
        # physical env alone (sharding hints degrade to no-ops).
        try:
            from jax._src import mesh as mesh_lib

            abstract_ctx = mesh_lib.set_abstract_mesh(mesh.abstract_mesh)
        except Exception:
            with mesh:
                yield mesh
            return
        with mesh, abstract_ctx:
            yield mesh


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    ``axis_names`` marks the *manual* axes (newer partial-auto spelling);
    on the old API the complement becomes the ``auto`` set. ``check_vma``
    maps to the old ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma) if check_vma is not None
                      else True, **kw)


def _register_missing_batching_rules() -> None:
    """0.4.x lacks a vmap rule for ``optimization_barrier`` — the barrier is
    per-element, so batching is transparent: bind on the batched operands and
    pass the batch dims through. (Vmapped expert matmuls hit this via the
    "xla" strategy's dequant pin.)"""
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import lax as lax_internal

        p = lax_internal.optimization_barrier_p
        if p not in batching.primitive_batchers:
            def _batcher(args, dims):
                return p.bind(*args), dims

            batching.primitive_batchers[p] = _batcher
    except Exception:  # pragma: no cover - internals moved; rule exists
        pass


_register_missing_batching_rules()
