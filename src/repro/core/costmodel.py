"""Analytic performance models.

Two machines are modeled:

1. **Ascend 910** (the paper's hardware) — a mechanistic three-phase model of
   Alg. 1 used to *reproduce the paper's measured trends* (Fig. 2: Split-K vs
   data-parallel; Fig. 3: W4A16 ≤1.48× over FP16). The decoupled-architecture
   constraint is explicit: dequantized weights round-trip through the
   GM/L2 path between vector and cube cores.

2. **TPU v5e** (our target) — the roofline constants used by
   benchmarks/roofline.py for the dry-run analysis, plus a fused-kernel
   model showing the round-trip term vanishing (the paper's Future-Work
   "direct data path", which the TPU core has).

The Ascend model is *calibrated, not measured*: compute/HBM constants are
public datasheet numbers; (bw_l2, bw_sat_cores, launch_s) are fit by grid
search so the model reproduces the paper's headline numbers — Split-K
speedup range [1.00, 1.78] vs the paper's [1.01, 1.74] and a W4A16-vs-FP16
cap of 1.47x vs the paper's 1.48x (see tests/test_costmodel.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AscendSpec:
    cube_flops: float = 256e12        # FP16 MACs/s aggregate (910)
    bw_gm: float = 1.1e12             # HBM bytes/s
    bw_l2: float = 2.2e12             # on-chip L2 path (vector↔cube round-trip)
    num_cores: int = 32               # AI cores (1 cube + 2 vector each)
    bw_sat_cores: int = 10           # cores needed to saturate GM bandwidth —
                                      # an underfilled grid can't pull peak BW;
                                      # this is WHY Split-K wins at K≫N/small M
    launch_s: float = 3e-6            # kernel-launch + sync overhead
    block_m: int = 128
    block_n: int = 256
    block_k: int = 256


@dataclasses.dataclass(frozen=True)
class TPUv5eSpec:
    flops: float = 197e12             # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    vmem_bytes: int = 128 * 2 ** 20


ASCEND = AscendSpec()
TPU_V5E = TPUv5eSpec()


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Ascend 910 model (paper Alg. 1)
# ---------------------------------------------------------------------------

def _wave_efficiency(tiles: int, cores: int) -> float:
    """Cube-core utilization with wave quantization: the last wave may be
    partially filled — the effect behind the paper's Fig. 2."""
    if tiles >= cores:
        waves = _ceil_div(tiles, cores)
        return tiles / (waves * cores)
    return tiles / cores


def gemm_time_ascend(M: int, N: int, K: int, *, split_k: int = 1,
                     weight_bytes_per_elt: float = 2.0,
                     weight_bw: Optional[float] = None,
                     spec: AscendSpec = ASCEND) -> float:
    """Time of one tiled GEMM phase (data-parallel if split_k == 1).

    weight_bytes_per_elt / weight_bw let the caller model where B comes
    from: GM fp16 (2.0, bw_gm), GM int4 (0.5, bw_gm) or the L2-resident
    dequant workspace (2.0, bw_l2).
    """
    weight_bw = weight_bw or spec.bw_gm
    m, n = spec.block_m, spec.block_n
    tiles = _ceil_div(M, m) * _ceil_div(N, n) * split_k
    eff = _wave_efficiency(tiles, spec.num_cores)
    t_compute = (2 * M * N * K) / (spec.cube_flops * eff)
    # memory bandwidth scales with active cores until saturation — the
    # decoupled-architecture effect behind the paper's Fig. 2
    bw_frac = min(1.0, min(tiles, spec.num_cores) / spec.bw_sat_cores)
    # A re-read per N-tile wave; B re-read per M-tile (M small → once)
    a_traffic = 2 * M * K * max(1, _ceil_div(N, n * spec.num_cores))
    b_traffic = weight_bytes_per_elt * K * N * _ceil_div(M, m)
    c_traffic = (4 if split_k > 1 else 2) * M * N * split_k
    t_mem = (a_traffic / spec.bw_gm + b_traffic / weight_bw
             + c_traffic / spec.bw_gm) / bw_frac
    return max(t_compute, t_mem) + spec.launch_s


def w4a16_time_ascend(M: int, N: int, K: int, *, split_k: int = 1,
                      spec: AscendSpec = ASCEND) -> float:
    """Full three-phase W4A16 pipeline (paper Alg. 1).

    Phase 1 (AIV): read INT4 from GM, write FP16 workspace (L2 path —
    this is THE decoupled-architecture round-trip the paper measures).
    Phase 2 (AIC): Split-K GEMM, weights from the L2-resident workspace.
    Phase 3 (AIV): reduce S partials + downcast.
    """
    t1 = (0.5 * K * N) / spec.bw_gm + (2 * K * N) / spec.bw_l2 + spec.launch_s
    t2 = gemm_time_ascend(M, N, K, split_k=split_k,
                          weight_bytes_per_elt=2.0, weight_bw=spec.bw_l2,
                          spec=spec)
    t3 = 0.0
    if split_k > 1:
        t3 = (4 * M * N * split_k + 2 * M * N) / spec.bw_gm + spec.launch_s
    return t1 + t2 + t3


def fp16_time_ascend(M: int, N: int, K: int,
                     spec: AscendSpec = ASCEND) -> float:
    """Native FP16×FP16 (the paper's PyTorch baseline): data-parallel,
    FP16 weights straight from GM."""
    return gemm_time_ascend(M, N, K, split_k=1,
                            weight_bytes_per_elt=2.0, weight_bw=spec.bw_gm,
                            spec=spec)


def best_split_k_ascend(M: int, N: int, K: int,
                        spec: AscendSpec = ASCEND) -> int:
    best, best_t = 1, float("inf")
    for s in (1, 2, 4, 8, 16):
        if K % s:
            continue
        t = w4a16_time_ascend(M, N, K, split_k=s, spec=spec)
        if t < best_t:
            best, best_t = s, t
    return best


def splitk_speedup_ascend(M: int, N: int, K: int,
                          spec: AscendSpec = ASCEND) -> float:
    """Paper Fig. 2: best Split-K W4A16 vs data-parallel W4A16."""
    t_dp = w4a16_time_ascend(M, N, K, split_k=1, spec=spec)
    t_sk = w4a16_time_ascend(
        M, N, K, split_k=best_split_k_ascend(M, N, K, spec), spec=spec)
    return t_dp / t_sk


def w4a16_speedup_ascend(M: int, N: int, K: int,
                         spec: AscendSpec = ASCEND) -> float:
    """Paper Fig. 3: best-split W4A16 vs native FP16."""
    s = best_split_k_ascend(M, N, K, spec)
    return fp16_time_ascend(M, N, K, spec) / \
        w4a16_time_ascend(M, N, K, split_k=s, spec=spec)


# ---------------------------------------------------------------------------
# TPU v5e fused-kernel model (the beyond-paper comparison)
# ---------------------------------------------------------------------------

def w4a16_time_tpu_fused(M: int, N: int, K: int,
                         spec: TPUv5eSpec = TPU_V5E) -> float:
    """Fused kernel: INT4 weights cross HBM once; dequant lives in VMEM.
    No round-trip term — the 'direct vector→cube data path'."""
    traffic = 2 * M * K + 0.5 * K * N + 2 * M * N
    return max((2 * M * N * K) / spec.flops, traffic / spec.hbm_bw)


def w4a16_time_tpu_decoupled(M: int, N: int, K: int, *, split_k: int = 1,
                             spec: TPUv5eSpec = TPU_V5E) -> float:
    """Paper-faithful pipeline on TPU: workspace round-trips through HBM
    (TPU has no shared L2 between kernels — the penalty is *worse* than
    Ascend's, which is exactly why the fused kernel is the right port)."""
    t1 = (0.5 * K * N + 2 * K * N) / spec.hbm_bw
    t2 = max((2 * M * N * K) / spec.flops,
             (2 * M * K + 2 * K * N + 4 * M * N * split_k) / spec.hbm_bw)
    t3 = (4 * M * N * split_k + 2 * M * N) / spec.hbm_bw if split_k > 1 else 0
    return t1 + t2 + t3


def fp16_time_tpu(M: int, N: int, K: int,
                  spec: TPUv5eSpec = TPU_V5E) -> float:
    traffic = 2 * M * K + 2 * K * N + 2 * M * N
    return max((2 * M * N * K) / spec.flops, traffic / spec.hbm_bw)


def w8a16_time_tpu_fused(M: int, N: int, K: int,
                         spec: TPUv5eSpec = TPU_V5E) -> float:
    """Fused per-channel INT8 kernel: int8 weight rows cross HBM once
    (K·N bytes, half of fp16) plus one fp32 scale row; dequant in VMEM."""
    traffic = 2 * M * K + 1.0 * K * N + 4 * N + 2 * M * N
    return max((2 * M * N * K) / spec.flops, traffic / spec.hbm_bw)


def w4a8_time_tpu_fused(M: int, N: int, K: int, *, group: int = 128,
                        spec: TPUv5eSpec = TPU_V5E) -> float:
    """Fused W4A8 kernel: int8 activations (M·K bytes, half of fp16),
    packed int4 weights (K·N/2) + fp32 group scales; int8×int8 MXU dots at
    twice the bf16 MAC rate (v5e int8 peak is 2× bf16)."""
    traffic = M * K + 0.5 * K * N + 4.0 * K * N / max(group, 1) + 2 * M * N
    return max((2 * M * N * K) / (2 * spec.flops), traffic / spec.hbm_bw)


# ---------------------------------------------------------------------------
# Decode-attention traffic model (ring vs gather vs fused-paged)
# ---------------------------------------------------------------------------
#
# Decode attention is the same bottleneck the paper measures for W4A16
# GEMM, transposed onto the KV cache: bandwidth-bound, and the naive
# quantized path pays an extra round-trip through global memory (gather +
# dequantize to an HBM staging buffer, then read it back for attention).
# These entries price that round-trip so the planner can charge it.

def kv_bytes_per_token(Hkv: int, D: int, *, quantized: bool,
                       act_bytes: int = 2) -> float:
    """HBM bytes to read one cached token's K+V across all kv-heads:
    payload (int8 or the activation dtype) plus the per-(token, head)
    fp32 scale pair for quantized formats."""
    payload = 1 if quantized else act_bytes
    scales = 2 * 4 * Hkv if quantized else 0
    return 2 * payload * Hkv * D + scales


def paged_attn_bytes(path: str, B: int, Hq: int, Hkv: int, D: int,
                     ctx: int, *, quantized: bool, act_bytes: int = 2,
                     kv_partitions: int = 1, q_len: int = 1) -> float:
    """HBM bytes moved by one attention step of ``q_len`` queries per row
    over a ctx-token window, per path:

    - ``ring``: dense fp16 ring buffer, read once (ring stores no
      quantized payloads).
    - ``gather``: pool read + the dequantized window *written to HBM and
      read back* — the two-pass round-trip the fused kernel deletes. The
      window materialization is charged in full regardless of ``q_len``:
      a prefill chunk or verify step gathers exactly as many bytes as a
      single decode token does.
    - ``fused``: pool read once + O(S·q_len) combine partials.

    For ``q_len > 1`` (chunked prefill / speculative verify) both paged
    paths additionally stage the chunk's own quantize-roundtripped K/V
    segment and read it back — identical work, charged to both.
    """
    q_out = 2 * B * q_len * Hq * D * act_bytes      # q in, out back
    window = B * ctx
    dense_tok = 2 * act_bytes * Hkv * D             # one token's K+V raw
    seg = 2 * B * q_len * dense_tok if q_len > 1 else 0
    if path == "ring":
        return window * dense_tok + q_out
    pool = window * kv_bytes_per_token(Hkv, D, quantized=quantized,
                                       act_bytes=act_bytes)
    if path == "gather":
        staged = window * dense_tok                 # dequantized window
        return pool + 2 * staged + seg + q_out      # write + read back
    if path == "fused":
        partials = kv_partitions * B * q_len * Hq * (D + 2) * 4 * 2
        return pool + seg + q_out + partials
    raise ValueError(f"unknown attention path {path!r} "
                     "(expected ring | gather | fused)")


def attn_decode_time_tpu(path: str, B: int, Hq: int, Hkv: int, D: int,
                         ctx: int, *, quantized: bool, act_bytes: int = 2,
                         kv_partitions: int = 1, q_len: int = 1,
                         spec: TPUv5eSpec = TPU_V5E) -> float:
    """Roofline time of one attention step (``q_len`` queries per row):
    QK^T + PV flops vs the path's HBM traffic. Decode and chunk-sized
    prefill are both firmly bandwidth-bound (arithmetic intensity ~q_len
    flops/byte at serving chunk sizes), so the bytes term decides the
    ranking."""
    flops = 4 * B * q_len * Hq * D * ctx            # QK^T + PV
    bytes_moved = paged_attn_bytes(
        path, B, Hq, Hkv, D, ctx, quantized=quantized,
        act_bytes=act_bytes, kv_partitions=kv_partitions, q_len=q_len)
    return max(flops / spec.flops, bytes_moved / spec.hbm_bw)
