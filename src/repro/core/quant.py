"""Quantization core: first-class formats over the paper's W4A16 substrate.

The paper's kernel is one point in a family of weight-quantized GEMMs. This
module makes the family explicit: a :class:`QuantFormat` is a frozen,
JSON-serializable descriptor (weight bits, packing layout, scale
granularity, symmetric/zero-point, activation dtype) registered by name, and
every :class:`QuantizedTensor` carries the format it was produced with.
``quantize`` / ``dequantize`` / ``pack_weights`` / ``unpack_weights``
dispatch through the format instead of through scattered kwargs.

Built-in formats (see :func:`available_formats`):

  ``w4a16_g128``    — the paper's format and the default: INT4 weights
                      packed two-per-byte along K, group-128 scales,
                      floating activations (paper Eq. 1/2).
  ``w8a16_channel`` — INT8 weights, one scale per output channel,
                      floating activations.
  ``w4a8_g128``     — INT4 weights with group-128 scales plus *dynamic
                      per-token INT8 activations* (LiquidGEMM-style W4A8);
                      executed by the XLA reference path, see
                      :func:`w4a8_matmul_ref`.

Quantization math (paper Eq. 1, generalized to b bits):

    x_q = round(x / s) + z          (z = 0 for symmetric)
    Dequant(x_q) = s * (x_q - z)    (paper Eq. 2)

Storage convention
------------------
Weights are ``(K, N)`` (contraction dim first, like ``x @ w``). For 4-bit
formats two INT4 values are packed per ``int8`` byte **along K**:

    byte[k, n] = (q[2k+1, n] << 4) | (q[2k, n] & 0xF)

so the packed tensor is ``(K//2, N)`` int8 — byte-identical footprint to the
Ascend INT32-nibble packing (K*N/2 bytes). 8-bit formats store ``(K, N)``
int8 rows directly. N stays the minor (lane) dimension, which is what the
TPU kernels want.

Scales (and optional zero-points) are ``(K/group, N)`` for group
granularity, ``(1, N)`` for per-channel, ``(1, 1)`` for per-tensor. In all
cases ``QuantizedTensor.group_size`` holds the number of K rows sharing one
scale row (``K`` for channel/tensor), so ``jnp.repeat(scales, group_size,
axis=0)`` reconstructs the per-element scale for every granularity.
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

INT4_MIN = -8
INT4_MAX = 7
DEFAULT_GROUP_SIZE = 128
DEFAULT_FORMAT = "w4a16_g128"

_PACKINGS = ("int4_pairs_k", "int8_rows")
_GRANULARITIES = ("group", "channel", "tensor")


# ---------------------------------------------------------------------------
# QuantFormat: the descriptor + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """A quantization format: what the bits mean and how they are laid out.

    Frozen, hashable, JSON round-trips via to_dict/from_dict. ``act_dtype``
    is the *nominal* activation dtype: floating names ("bfloat16",
    "float16") mean native float activations (the kernels accept any float
    input); ``"int8"`` means activations are dynamically quantized per
    token at matmul time (W4A8).
    """

    name: str
    weight_bits: int = 4             # 4 | 8
    packing: str = "int4_pairs_k"    # int4_pairs_k | int8_rows
    scale_granularity: str = "group"  # group | channel | tensor
    group_size: int = DEFAULT_GROUP_SIZE   # K rows per scale ("group" only)
    symmetric: bool = True           # False => zero-points are stored
    act_dtype: str = "bfloat16"      # nominal activations; "int8" = dynamic

    def __post_init__(self):
        if self.packing not in _PACKINGS:
            raise ValueError(f"unknown packing {self.packing!r}; "
                             f"one of {_PACKINGS}")
        if self.scale_granularity not in _GRANULARITIES:
            raise ValueError(f"unknown scale granularity "
                             f"{self.scale_granularity!r}; "
                             f"one of {_GRANULARITIES}")
        want_bits = 4 if self.packing == "int4_pairs_k" else 8
        if self.weight_bits != want_bits:
            raise ValueError(f"packing {self.packing!r} stores "
                             f"{want_bits}-bit weights, got "
                             f"weight_bits={self.weight_bits}")
        if self.scale_granularity == "group" and self.group_size <= 0:
            raise ValueError("group granularity needs group_size > 0")

    # -- derived ----------------------------------------------------------
    @property
    def qmin(self) -> int:
        return -(1 << (self.weight_bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.weight_bits - 1)) - 1

    @property
    def pack_factor(self) -> int:
        """K rows represented per packed row (2 for nibble pairs)."""
        return 2 if self.packing == "int4_pairs_k" else 1

    @property
    def quantized_activations(self) -> bool:
        return self.act_dtype == "int8"

    def scale_rows(self, K: int) -> int:
        return K // self.group_size if self.scale_granularity == "group" \
            else 1

    # -- derived variants -------------------------------------------------
    def with_group_size(self, group_size: int) -> "QuantFormat":
        """This format with another group size (registered on demand).
        A no-op for channel/tensor granularity, where there are no groups."""
        if self.scale_granularity != "group" \
                or group_size == self.group_size:
            return self
        name, n = re.subn(r"_g\d+", f"_g{group_size}", self.name, count=1)
        if not n:
            name = f"{self.name}_g{group_size}"
        return register_format(
            dataclasses.replace(self, name=name, group_size=group_size))

    def with_symmetric(self, symmetric: bool) -> "QuantFormat":
        """Symmetric/asymmetric variant (``_asym`` name suffix toggles)."""
        if symmetric == self.symmetric:
            return self
        name = self.name[:-len("_asym")] if self.name.endswith("_asym") \
            else self.name + "_asym"
        return register_format(
            dataclasses.replace(self, name=name, symmetric=symmetric))

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "QuantFormat":
        return cls(**dict(d))


_FORMAT_REGISTRY: Dict[str, QuantFormat] = {}


def register_format(fmt: QuantFormat, *, overwrite: bool = False
                    ) -> QuantFormat:
    """Register ``fmt`` under its name and return it (usable as a plain
    call or chained). Re-registering an identical format is a no-op; a
    *different* format under an existing name raises unless
    ``overwrite=True``."""
    existing = _FORMAT_REGISTRY.get(fmt.name)
    if existing is not None and existing != fmt and not overwrite:
        raise ValueError(
            f"format {fmt.name!r} is already registered with different "
            f"fields; pass overwrite=True to replace it")
    _FORMAT_REGISTRY[fmt.name] = fmt
    return fmt


def get_format(name: str) -> QuantFormat:
    try:
        return _FORMAT_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown quantization format {name!r}; registered: "
            f"{available_formats()}") from None


def available_formats() -> Tuple[str, ...]:
    return tuple(_FORMAT_REGISTRY)


FormatLike = Union[None, str, QuantFormat, Mapping[str, Any]]


def resolve_format(spec: FormatLike) -> QuantFormat:
    """Resolve a name / QuantFormat / descriptor dict / None (the default
    format) to a registered QuantFormat. Unregistered descriptors are
    registered so their name resolves from then on."""
    if spec is None:
        return _FORMAT_REGISTRY[DEFAULT_FORMAT]
    if isinstance(spec, str):
        return get_format(spec)
    if isinstance(spec, QuantFormat):
        return register_format(spec)
    if isinstance(spec, Mapping):
        return register_format(QuantFormat.from_dict(spec))
    raise TypeError(f"cannot resolve a quantization format from "
                    f"{type(spec).__name__}")


def w4a16_format_for(group_size: int, *, symmetric: bool = True
                     ) -> QuantFormat:
    """The W4A16-family format for a group size — the default-format shim
    legacy call sites (bare ``group_size=`` kwargs, pre-format plan caches
    and checkpoints) resolve through."""
    fmt = _FORMAT_REGISTRY[DEFAULT_FORMAT].with_group_size(group_size)
    return fmt.with_symmetric(symmetric)


# The built-in formats. w4a16_g128 is the paper's format and the default;
# w8a16_channel and w4a8_g128 are the first two beyond-paper family members
# (cf. LiquidGEMM W4A8 in PAPERS.md).
W4A16_G128 = register_format(QuantFormat(
    name="w4a16_g128", weight_bits=4, packing="int4_pairs_k",
    scale_granularity="group", group_size=128, symmetric=True,
    act_dtype="bfloat16"))
W8A16_CHANNEL = register_format(QuantFormat(
    name="w8a16_channel", weight_bits=8, packing="int8_rows",
    scale_granularity="channel", group_size=0, symmetric=True,
    act_dtype="bfloat16"))
W4A8_G128 = register_format(QuantFormat(
    name="w4a8_g128", weight_bits=4, packing="int4_pairs_k",
    scale_granularity="group", group_size=128, symmetric=True,
    act_dtype="int8"))


# ---------------------------------------------------------------------------
# QuantizedTensor
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A quantized weight: packed payload + scales (+ zeros) + its format.

    ``format=None`` (the legacy constructor) infers the W4A16-family format
    from ``group_size`` and the presence of ``zeros`` — pre-format call
    sites and checkpoints keep working unchanged.
    """

    packed: jax.Array          # (K//pack_factor, N) int8
    scales: jax.Array          # (scale_rows, N) float32/bfloat16
    zeros: Optional[jax.Array]  # same shape as scales, or None (symmetric)
    group_size: int            # K rows per scale row (K for channel/tensor)
    out_dtype: jnp.dtype       # dtype dequantized weights materialize in
    format: Optional[QuantFormat] = None

    def __post_init__(self):
        if self.format is None:
            self.format = w4a16_format_for(
                self.group_size, symmetric=self.zeros is None)

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        children = (self.packed, self.scales, self.zeros)
        aux = (self.group_size, self.out_dtype, self.format)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales, zeros = children
        group_size, out_dtype, fmt = aux
        return cls(packed, scales, zeros, group_size, out_dtype, fmt)

    # -- convenience -------------------------------------------------------
    @property
    def shape(self):
        return (self.K, self.N)

    @property
    def K(self) -> int:
        return self.packed.shape[-2] * self.format.pack_factor

    @property
    def N(self) -> int:
        return self.packed.shape[-1]

    def nbytes_packed(self) -> int:
        n = self.packed.size  # 1 byte each
        n += self.scales.size * self.scales.dtype.itemsize
        if self.zeros is not None:
            n += self.zeros.size * self.zeros.dtype.itemsize
        return n


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4 values (stored in int8, range [-8, 7]) pairwise along axis 0.

    ``q`` has shape (K, N) with K even; returns (K//2, N) int8.
    """
    if q.shape[0] % 2:
        raise ValueError(f"K must be even to pack, got {q.shape}")
    lo = q[0::2].astype(jnp.uint8) & 0xF
    hi = q[1::2].astype(jnp.uint8) & 0xF
    return ((hi << 4) | lo).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` → (K, N) int8 in [-8, 7].

    Uses shift-based sign extension (``(b << 4) >> 4``), the same trick the
    paper's vector-core dequant uses and what lowers to cheap VPU ops on TPU.
    """
    b = packed.astype(jnp.int8)
    lo = jnp.left_shift(b, 4)
    lo = jnp.right_shift(lo, 4)          # arithmetic shift → sign-extended
    hi = jnp.right_shift(b, 4)
    k2, n = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)


def unpack_int8_rows(packed: jax.Array) -> jax.Array:
    """The ``int8_rows`` unpack: weight rows are stored directly as int8.

    Named for symmetry with :func:`unpack_int4` so format-generic code can
    dispatch by packing without special-casing the identity layout.
    """
    return packed.astype(jnp.int8)


def pack_weights(q: jax.Array, fmt: FormatLike = None) -> jax.Array:
    """Pack integer weight values per the format's layout."""
    fmt = resolve_format(fmt)
    if fmt.packing == "int4_pairs_k":
        return pack_int4(q)
    return q.astype(jnp.int8)            # int8_rows: stored as-is


def unpack_weights(packed: jax.Array, fmt: FormatLike = None) -> jax.Array:
    """Inverse of :func:`pack_weights` → (K, N) int8."""
    fmt = resolve_format(fmt)
    if fmt.packing == "int4_pairs_k":
        return unpack_int4(packed)
    return unpack_int8_rows(packed)


def per_channel_scales(qt: "QuantizedTensor"):
    """``(scales, zeros)`` broadcast to the (1, N) per-channel layout.

    Channel-granular scales are stored as (1, N) and pass through; tensor-
    granular (1, 1) scales broadcast across N so per-channel kernels can
    block them along the lane dimension. Group-granular tensors are
    refused — their scales vary along K and need the grouped kernels.
    """
    if qt.format.scale_granularity == "group":
        raise ValueError(
            f"format {qt.format.name!r} has group-granular scales; "
            f"per-channel kernels need channel or tensor granularity")
    N = qt.N
    scales = jnp.broadcast_to(qt.scales, (1, N))
    zeros = None if qt.zeros is None \
        else jnp.broadcast_to(qt.zeros, (1, N))
    return scales, zeros


# ---------------------------------------------------------------------------
# quantize / dequantize (format-dispatched)
# ---------------------------------------------------------------------------

def quantize(
    w: jax.Array,
    format: FormatLike = None,
    *,
    group_size: Optional[int] = None,
    symmetric: Optional[bool] = None,
    scale_dtype: jnp.dtype = jnp.float32,
    out_dtype: Optional[jnp.dtype] = None,
) -> QuantizedTensor:
    """Quantize a (K, N) weight matrix per ``format``.

    ``format`` may be a registered name, a QuantFormat, a descriptor dict,
    or None (the default ``w4a16_g128``). The legacy ``group_size=`` /
    ``symmetric=`` kwargs derive a variant of the chosen format, so
    pre-format call sites behave exactly as before.
    """
    fmt = resolve_format(format)
    if group_size is not None:
        fmt = fmt.with_group_size(group_size)
    if symmetric is not None:
        fmt = fmt.with_symmetric(symmetric)

    if w.ndim != 2:
        raise ValueError(f"quantize expects 2-D (K, N) weight, got {w.shape}")
    K, N = w.shape
    if fmt.packing == "int4_pairs_k" and K % 2:
        raise ValueError(f"K={K} must be even for {fmt.packing} packing")
    if fmt.scale_granularity == "group":
        g = fmt.group_size
        if K % g:
            raise ValueError(f"K={K} not divisible by group_size={g} "
                             f"(format {fmt.name!r})")
        if fmt.packing == "int4_pairs_k" and g % 2:
            raise ValueError("group_size must be even")
    else:
        g = K                           # channel/tensor: one group spans K
    out_dtype = jnp.dtype(out_dtype or w.dtype)

    gw = w.astype(jnp.float32).reshape(K // g, g, N)
    reduce_axes = (1, 2) if fmt.scale_granularity == "tensor" else (1,)
    keep = dict(axis=reduce_axes, keepdims=True)
    if fmt.symmetric:
        amax = jnp.max(jnp.abs(gw), **keep)
        s = jnp.maximum(amax / fmt.qmax, 1e-8)
        z = None
        q = jnp.round(gw / s)
    else:
        gmax = jnp.max(gw, **keep)
        gmin = jnp.min(gw, **keep)
        s = jnp.maximum((gmax - gmin) / (fmt.qmax - fmt.qmin), 1e-8)
        z = jnp.round(-gmin / s) + fmt.qmin                 # zero-point
        q = jnp.round(gw / s) + z
    q = jnp.clip(q, fmt.qmin, fmt.qmax).astype(jnp.int8).reshape(K, N)

    def flat(a):                         # drop the reduced group axis:
        return a[:, 0]                   # (K/g, N) | (1, N) | (1, 1)
    return QuantizedTensor(
        packed=pack_weights(q, fmt),
        scales=flat(s).astype(scale_dtype),
        zeros=None if z is None else flat(z).astype(scale_dtype),
        group_size=g,
        out_dtype=out_dtype,
        format=fmt,
    )


def dequantize(qt: QuantizedTensor) -> jax.Array:
    """Materialize the full (K, N) weight in ``qt.out_dtype`` (paper Eq. 2)."""
    q = unpack_weights(qt.packed, qt.format).astype(jnp.float32)
    K, N = q.shape
    g = qt.group_size

    def expand(a):                       # scale rows → per-element (K, .)
        return jnp.repeat(a.astype(jnp.float32), g, axis=0)
    if qt.zeros is not None:
        q = q - expand(qt.zeros)
    return (q * expand(qt.scales)).astype(qt.out_dtype)


# ---------------------------------------------------------------------------
# reference matmuls (pure jnp oracles; kernels are checked against these)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def w4a16_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """``x @ Dequant(W)`` — the paper's Eq. 2 computed the naive way.

    Valid for every float-activation format (w4a16 family, w8a16).
    """
    w = dequantize(qt)
    acc = jnp.dot(
        x.astype(qt.out_dtype), w, preferred_element_type=jnp.float32
    )
    return acc.astype(x.dtype)


def quantize_activations_int8(x: jax.Array):
    """Dynamic per-token symmetric INT8 activation quantization.

    Returns ``(x_q int8, x_scale fp32)`` with ``x_scale`` shaped like ``x``
    minus the last dim plus a keepdim (one scale per token/row) — the
    LiquidGEMM-style dynamic activation path of ``w4a8_*`` formats.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    s = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    return q, s


@partial(jax.jit, static_argnames=())
def w4a8_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """W4A8 GEMM: dynamic INT8 activations × INT4 weights, integer
    accumulation per K-group, scales applied at the group boundary:

        y[m, n] = xs[m] * sum_G ws[G, n] * sum_g xq[m, G, g] * wq[G, g, n]

    This is the XLA reference execution path for ``w4a8_*`` formats (a
    Pallas W4A8 kernel can plug into the same strategy slot later).
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    xq, xs = quantize_activations_int8(x2)
    wq = unpack_weights(qt.packed, qt.format)            # (K, N) int8
    N = wq.shape[-1]
    g = qt.group_size
    G = K // g
    acc = jnp.einsum(
        "mgk,gkn->mgn", xq.reshape(M, G, g), wq.reshape(G, g, N),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)                                # (M, G, N)
    if qt.zeros is not None:
        tok = jnp.sum(xq.reshape(M, G, g).astype(jnp.int32),
                      axis=2).astype(jnp.float32)        # (M, G)
        acc = acc - qt.zeros.astype(jnp.float32)[None] * tok[:, :, None]
    y = jnp.einsum("mgn,gn->mn", acc, qt.scales.astype(jnp.float32))
    return (y * xs).astype(x.dtype).reshape(*lead, N)


def quantization_error_bound(qt: QuantizedTensor) -> jax.Array:
    """Per-group max representable rounding error: |w - deq(q(w))| <= s/2."""
    return qt.scales.astype(jnp.float32) / 2.0


# ---------------------------------------------------------------------------
# KV-cache quantization formats
# ---------------------------------------------------------------------------
#
# Weight formats above describe a (K, N) GEMM operand; the KV cache is the
# *other* serving tensor whose HBM bytes dominate decode (the paper's
# memory-bound regime, LiquidGEMM's serving-scale point). A KVFormat is the
# analogous first-class descriptor for how cached K/V token vectors are
# stored in the paged block pool (runtime/kvcache.py): either the cache
# dtype verbatim (``kv_fp16``) or INT8 with one dynamic scale per token per
# KV head (``kv8_channel``), dequantized on gather into the same cache-dtype
# attention path ``decode_attention`` already uses.

@dataclasses.dataclass(frozen=True)
class KVFormat:
    """How cached K/V vectors are stored in the paged KV block pool.

    ``bits=16`` is the passthrough layout (pool holds the cache dtype,
    no scales). ``bits=8`` stores int8 payloads plus one fp32 scale per
    (token, kv-head) — "channel" granularity over the head axis, the KV
    analogue of ``w8a16_channel``'s per-output-channel scales.
    """

    name: str
    bits: int = 16                   # 16 (passthrough) | 8
    scale_granularity: str = "none"  # none | channel (per token, per head)

    def __post_init__(self):
        if self.bits not in (8, 16):
            raise ValueError(f"KVFormat bits must be 8 or 16, got {self.bits}")
        if self.bits == 16 and self.scale_granularity != "none":
            raise ValueError("16-bit KV passthrough stores no scales")
        if self.bits == 8 and self.scale_granularity != "channel":
            raise ValueError("8-bit KV needs per-head 'channel' scales")

    @property
    def quantized(self) -> bool:
        return self.bits == 8

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


_KV_FORMAT_REGISTRY: Dict[str, KVFormat] = {}
DEFAULT_KV_FORMAT = "kv_fp16"


def register_kv_format(fmt: KVFormat, *, overwrite: bool = False) -> KVFormat:
    existing = _KV_FORMAT_REGISTRY.get(fmt.name)
    if existing is not None and existing != fmt and not overwrite:
        raise ValueError(
            f"KV format {fmt.name!r} is already registered with different "
            f"fields; pass overwrite=True to replace it")
    _KV_FORMAT_REGISTRY[fmt.name] = fmt
    return fmt


def get_kv_format(name: str) -> KVFormat:
    try:
        return _KV_FORMAT_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown KV-cache format {name!r}; registered: "
            f"{available_kv_formats()}") from None


def available_kv_formats() -> Tuple[str, ...]:
    return tuple(_KV_FORMAT_REGISTRY)


KV_FP16 = register_kv_format(KVFormat("kv_fp16", bits=16,
                                      scale_granularity="none"))
KV8_CHANNEL = register_kv_format(KVFormat("kv8_channel", bits=8,
                                          scale_granularity="channel"))


def kv_quantize(x: jax.Array, fmt: KVFormat):
    """Quantize K/V token vectors ``(..., Hkv, D)`` per ``fmt``.

    Returns ``(payload, scales)``: int8 payload + fp32 per-(token, head)
    scales for ``kv8_channel``; ``(x, None)`` passthrough for ``kv_fp16``.
    """
    if not fmt.quantized:
        return x, None
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)    # (..., Hkv, 1)
    s = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    return q, s[..., 0]


def kv_dequantize(payload: jax.Array, scales, fmt: KVFormat, dtype):
    """Inverse of :func:`kv_quantize` — materializes ``dtype`` (the cache
    dtype the attention dots already run in)."""
    if not fmt.quantized:
        return payload.astype(dtype)
    return (payload.astype(jnp.float32)
            * scales.astype(jnp.float32)[..., None]).astype(dtype)
