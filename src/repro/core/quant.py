"""INT4 weight-only quantization core (the paper's W4A16 substrate).

Implements uniform affine/symmetric group-wise quantization (paper Eq. 1):

    x_q = round(x / s) + z          (z = 0 for symmetric)
    Dequant(x_q) = s * (x_q - z)    (paper Eq. 2)

Storage convention
------------------
Weights are ``(K, N)`` (contraction dim first, like ``x @ w``).  Two INT4
values are packed per ``int8`` byte **along K**:

    byte[k, n] = (q[2k+1, n] << 4) | (q[2k, n] & 0xF)

so the packed tensor is ``(K//2, N)`` int8 — byte-identical footprint to the
Ascend INT32-nibble packing (K*N/2 bytes).  N stays the minor (lane)
dimension, which is what the TPU kernels want.

Scales (and optional zero-points) are per ``(K-group, N)``:
``scales[(k // group_size), n]``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

INT4_MIN = -8
INT4_MAX = 7
DEFAULT_GROUP_SIZE = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A W4A16 weight: packed int4 payload + group-wise scales (+ zeros)."""

    packed: jax.Array          # (K//2, N) int8, two nibbles per byte
    scales: jax.Array          # (K//group_size, N) float32/bfloat16
    zeros: Optional[jax.Array]  # (K//group_size, N) same dtype, or None (symmetric)
    group_size: int
    out_dtype: jnp.dtype       # dtype dequantized weights are materialized in

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        children = (self.packed, self.scales, self.zeros)
        aux = (self.group_size, self.out_dtype)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales, zeros = children
        group_size, out_dtype = aux
        return cls(packed, scales, zeros, group_size, out_dtype)

    # -- convenience -------------------------------------------------------
    @property
    def shape(self):
        return (self.packed.shape[0] * 2, self.packed.shape[1])

    @property
    def K(self) -> int:
        return self.packed.shape[0] * 2

    @property
    def N(self) -> int:
        return self.packed.shape[1]

    def nbytes_packed(self) -> int:
        n = self.packed.size  # 1 byte each
        n += self.scales.size * self.scales.dtype.itemsize
        if self.zeros is not None:
            n += self.zeros.size * self.zeros.dtype.itemsize
        return n


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4 values (stored in int8, range [-8, 7]) pairwise along axis 0.

    ``q`` has shape (K, N) with K even; returns (K//2, N) int8.
    """
    if q.shape[0] % 2:
        raise ValueError(f"K must be even to pack, got {q.shape}")
    lo = q[0::2].astype(jnp.uint8) & 0xF
    hi = q[1::2].astype(jnp.uint8) & 0xF
    return ((hi << 4) | lo).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` → (K, N) int8 in [-8, 7].

    Uses shift-based sign extension (``(b << 4) >> 4``), the same trick the
    paper's vector-core dequant uses and what lowers to cheap VPU ops on TPU.
    """
    b = packed.astype(jnp.int8)
    lo = jnp.left_shift(b, 4)
    lo = jnp.right_shift(lo, 4)          # arithmetic shift → sign-extended
    hi = jnp.right_shift(b, 4)
    k2, n = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------

def quantize(
    w: jax.Array,
    *,
    group_size: int = DEFAULT_GROUP_SIZE,
    symmetric: bool = True,
    scale_dtype: jnp.dtype = jnp.float32,
    out_dtype: Optional[jnp.dtype] = None,
) -> QuantizedTensor:
    """Group-wise INT4 quantization of a (K, N) weight matrix."""
    if w.ndim != 2:
        raise ValueError(f"quantize expects 2-D (K, N) weight, got {w.shape}")
    K, N = w.shape
    if K % group_size:
        raise ValueError(f"K={K} not divisible by group_size={group_size}")
    if (K // group_size) % 1 or group_size % 2:
        raise ValueError("group_size must be even")
    out_dtype = jnp.dtype(out_dtype or w.dtype)

    g = w.astype(jnp.float32).reshape(K // group_size, group_size, N)
    if symmetric:
        amax = jnp.max(jnp.abs(g), axis=1)                      # (K/g, N)
        s = jnp.maximum(amax / INT4_MAX, 1e-8)
        z = None
        q = jnp.round(g / s[:, None, :])
    else:
        gmax = jnp.max(g, axis=1)
        gmin = jnp.min(g, axis=1)
        s = jnp.maximum((gmax - gmin) / (INT4_MAX - INT4_MIN), 1e-8)
        z = jnp.round(-gmin / s) + INT4_MIN                     # zero-point
        q = jnp.round(g / s[:, None, :]) + z[:, None, :]
    q = jnp.clip(q, INT4_MIN, INT4_MAX).astype(jnp.int8).reshape(K, N)
    return QuantizedTensor(
        packed=pack_int4(q),
        scales=s.astype(scale_dtype),
        zeros=None if z is None else z.astype(scale_dtype),
        group_size=group_size,
        out_dtype=out_dtype,
    )


def dequantize(qt: QuantizedTensor) -> jax.Array:
    """Materialize the full (K, N) weight in ``qt.out_dtype`` (paper Eq. 2)."""
    q = unpack_int4(qt.packed).astype(jnp.float32)
    K, N = q.shape
    g = qt.group_size
    s = jnp.repeat(qt.scales.astype(jnp.float32), g, axis=0)    # (K, N)
    if qt.zeros is not None:
        z = jnp.repeat(qt.zeros.astype(jnp.float32), g, axis=0)
        q = q - z
    return (q * s).astype(qt.out_dtype)


# ---------------------------------------------------------------------------
# reference W4A16 matmul (pure jnp oracle; kernels are checked against this)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def w4a16_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """``x @ Dequant(W)`` — the paper's Eq. 2 computed the naive way."""
    w = dequantize(qt)
    acc = jnp.dot(
        x.astype(qt.out_dtype), w, preferred_element_type=jnp.float32
    )
    return acc.astype(x.dtype)


def quantization_error_bound(qt: QuantizedTensor) -> jax.Array:
    """Per-group max representable rounding error: |w - deq(q(w))| <= s/2."""
    return qt.scales.astype(jnp.float32) / 2.0
