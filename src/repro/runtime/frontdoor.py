"""Async serving front door: HTTP request queue + SSE token streaming.

``launch/serve.py`` simulates arrivals in-process; this module is the real
request path over the same engine. A stdlib-``asyncio`` HTTP/1.1 server

  - accepts ``POST /v1/generate`` requests into a **bounded admission
    queue** (queue full → 429 before anything is computed; a request whose
    deadline expires while queued → 408, dropped *before prefill*),
  - drives :class:`ServingEngine` through its re-entrant stepper API
    (``start``/``submit``/``step``/``cancel``) from a single driver task —
    new requests enter and cancellations apply **between decode steps**,
  - streams each request's tokens back as SSE chunks as every decode /
    verify step flushes them (:class:`StepEvents`), and
  - evicts a slot mid-decode when its client disconnects, freeing its KV
    pages for waiting requests (``engine.cancel``).

``GET /metrics`` renders the shared :class:`MetricsRegistry`
(``runtime/metrics.py``) — queue depth, admission outcomes, TTFT and
end-to-end latency quantiles — sampled once per engine step; the same
numbers land in the final :class:`ServeReport`, so the endpoint and the
report cannot disagree.

Wire format (one connection per request, ``Connection: close``):

    POST /v1/generate         {"prompt": [ints], "max_new_tokens": N,
                               "deadline_s": S?, "priority": P?,
                               "prefix_embeds"/"audio_embeds": [[floats]]?}
    → 200 text/event-stream   data: {"rid": R, "tokens": [..]}\\n\\n  per
                              engine step, then
                              event: done
                              data: {"rid": R, "n": total}\\n\\n
    → 429 queue full / 408 deadline expired / 400 bad request (JSON body)
    GET /metrics              Prometheus text exposition
    GET /healthz              {"ok": true, ...}

The engine's jitted steps are synchronous JAX calls; the driver runs them
in a thread-pool executor so the event loop keeps accepting connections
and observing disconnects while a step computes. Only the driver task
touches the engine — handlers talk to it through the queue and the cancel
set, which is what makes the whole thing lock-free.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import json
import math
import time
from typing import Dict, List, Optional

from repro.runtime.engine import Request, ServeReport, ServingEngine
from repro.runtime.metrics import MetricsRegistry

__all__ = ["FrontDoor", "QueueSettings", "sse_decode_tokens"]


@dataclasses.dataclass(frozen=True)
class QueueSettings:
    """Admission-queue policy knobs (see ``launch/presets.py`` for the
    per-arch defaults behind ``--queue-depth`` / ``--deadline-s``)."""

    queue_depth: int = 64           # pending requests before 429
    default_deadline_s: Optional[float] = None   # applied when the client
                                                 # sends no deadline_s
    idle_wait_s: float = 0.02       # driver poll interval when idle


class _Pending:
    """One queued request plus its streaming plumbing."""

    __slots__ = ("req", "t_enqueue", "deadline", "events", "gate")

    def __init__(self, req: Request, deadline: Optional[float]):
        self.req = req
        self.t_enqueue = time.perf_counter()
        self.deadline = deadline            # absolute perf_counter() time
        self.events: asyncio.Queue = asyncio.Queue()
        self.gate: asyncio.Future = \
            asyncio.get_running_loop().create_future()
        # gate resolves to "submitted" | "expired" before any body bytes
        # are written, so the status line can still be 408


class FrontDoor:
    """Asyncio HTTP front end over a :class:`ServingEngine`.

    The engine must be constructed with ``admission="priority"`` to honor
    ``priority``/``deadline_s`` ordering (plain FIFO also works — the
    queue semantics are identical, only admission *order* changes).
    """

    def __init__(self, engine: ServingEngine, *,
                 settings: QueueSettings = QueueSettings(),
                 metrics: Optional[MetricsRegistry] = None):
        self.engine = engine
        self.settings = settings
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        engine.metrics = self.metrics
        self.queue: List[_Pending] = []          # admission queue (bounded)
        self._streams: Dict[int, _Pending] = {}  # rid → entry (submitted)
        self._cancels: set = set()               # rids to cancel next step
        self._rids = itertools.count()
        self._server: Optional[asyncio.base_events.Server] = None
        self._driver: Optional[asyncio.Task] = None
        self._running = False
        self.host = self.port = None
        # pre-register the admission series so /metrics shows zeros from
        # the first scrape, not only after the first rejection
        m = self.metrics
        m.counter("frontdoor_admitted_total", "requests accepted into the "
                  "admission queue")
        m.counter("frontdoor_rejected_429_total", "queue-full rejections")
        m.counter("frontdoor_rejected_408_total", "expired-deadline drops")
        m.counter("frontdoor_cancelled_total", "client-disconnect cancels")
        m.gauge("frontdoor_queue_depth", "requests in the admission queue")
        m.histogram("frontdoor_queue_seconds", "enqueue to engine submit")

    # -- lifecycle ---------------------------------------------------------

    async def serve(self, host: str = "127.0.0.1", port: int = 0, *,
                    start_driver: bool = True) -> None:
        """Bind, start accepting, and (unless testing admission alone)
        start the engine driver. ``port=0`` binds an ephemeral port,
        published on ``self.port``."""
        self.engine.start()
        self._running = True
        self._server = await asyncio.start_server(self._handle, host, port)
        self.host, self.port = self._server.sockets[0].getsockname()[:2]
        if start_driver:
            self.start_driver()

    def start_driver(self) -> None:
        if self._driver is None:
            self._driver = asyncio.create_task(self._drive())

    async def shutdown(self, *, drain: bool = True) -> ServeReport:
        """Stop accepting; optionally finish everything queued/resident,
        then stop the driver and return the final report."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if drain and self._driver is not None:
            while self.queue or self.engine.has_work() or self._streams:
                await asyncio.sleep(self.settings.idle_wait_s)
        self._running = False
        if self._driver is not None:
            await self._driver
            self._driver = None
        return self.report()

    def report(self) -> ServeReport:
        """The engine's report with the front door's queue economics
        folded in (429/408 counts live here — by definition the engine
        never saw those requests)."""
        return self.engine.report

    # -- driver: the only task that touches the engine ---------------------

    async def _drive(self) -> None:
        loop = asyncio.get_running_loop()
        while self._running:
            self._apply_cancels()
            self._admit_from_queue()
            if not self.engine.has_work():
                await asyncio.sleep(self.settings.idle_wait_s)
                continue
            # run the jitted step off-loop so accepts/disconnects stay live
            ev = await loop.run_in_executor(None, self.engine.step)
            self._dispatch(ev)

    def _apply_cancels(self) -> None:
        report = self.engine.report
        while self._cancels:
            rid = self._cancels.pop()
            entry = self._streams.pop(rid, None)
            queued = next((p for p in self.queue if p.req.rid == rid), None)
            if queued is not None:
                self.queue.remove(queued)
                if not queued.gate.done():
                    queued.gate.set_result("cancelled")
            if self.engine.cancel(rid) or queued is not None:
                self.metrics.counter("frontdoor_cancelled_total").inc()
                if queued is not None and rid not in report.cancelled:
                    report.cancelled[rid] = []
            if entry is not None:
                entry.events.put_nowait(("cancelled", None))
        self.metrics.gauge("frontdoor_queue_depth").set(len(self.queue))

    def _admit_from_queue(self) -> None:
        """Feed queued requests to the engine; expired deadlines are
        dropped here — before prefill, before a slot, before any compute —
        and their clients get the 408. Only as many requests as could
        occupy a slot next step move over; the rest *stay in the front-door
        queue*, where their deadlines keep being checked every driver
        iteration (the engine's internal queue never grows beyond the slot
        pool, so queue depth is observable in one place)."""
        report = self.engine.report
        now = time.perf_counter()
        still: List[_Pending] = []
        for p in self.queue:
            if p.deadline is not None and now > p.deadline:
                report.rejected_408 += 1
                self.metrics.counter("frontdoor_rejected_408_total").inc()
                if not p.gate.done():
                    p.gate.set_result("expired")
            else:
                still.append(p)
        free = sum(1 for s in self.engine._slots if s is None)
        budget = max(0, free - len(self.engine._waiting))
        if self.engine.admission == "priority":
            still.sort(key=lambda p: (
                -(p.req.priority or 0),
                p.deadline if p.deadline is not None else math.inf,
                p.req.rid))
        for p in still[:budget]:
            wait = now - p.t_enqueue
            report.queue_wait[p.req.rid] = wait
            self.metrics.histogram("frontdoor_queue_seconds").observe(wait)
            self.engine.submit(p.req)
            self._streams[p.req.rid] = p
            if not p.gate.done():
                p.gate.set_result("submitted")
        self.queue[:] = still[budget:]
        self.metrics.gauge("frontdoor_queue_depth").set(len(self.queue))

    def _dispatch(self, ev) -> None:
        """Fan one step's events out to the per-request streams."""
        for rid, toks in ev.emitted.items():
            entry = self._streams.get(rid)
            if entry is not None:
                entry.events.put_nowait(("tokens", list(toks)))
        for rid in ev.finished:
            entry = self._streams.pop(rid, None)
            if entry is not None:
                entry.events.put_nowait(("done", None))

    # -- HTTP --------------------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, path, headers = await _read_head(reader)
            if method is None:
                return
            if method == "GET" and path == "/metrics":
                await _respond(writer, 200, self.metrics.render(),
                               ctype="text/plain; version=0.0.4")
            elif method == "GET" and path == "/healthz":
                await _respond_json(writer, 200, {
                    "ok": True, "queued": len(self.queue),
                    "resident": sum(1 for s in self.engine._slots
                                    if s is not None)})
            elif method == "POST" and path == "/v1/generate":
                body = await reader.readexactly(
                    int(headers.get("content-length", 0)))
                await self._generate(reader, writer, body)
            else:
                await _respond_json(writer, 404, {"error": "not found"})
        except (asyncio.IncompleteReadError, ConnectionResetError,
                BrokenPipeError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    async def _generate(self, reader, writer, body: bytes) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = spec["prompt"]
            if (not isinstance(prompt, list) or not prompt
                    or not all(isinstance(t, int) for t in prompt)):
                raise ValueError("prompt must be a non-empty int list")
            max_new = int(spec.get("max_new_tokens",
                                   self.engine.max_new_tokens))
            deadline_s = spec.get("deadline_s",
                                  self.settings.default_deadline_s)
            priority = int(spec.get("priority", 0))
            prefix_embeds = spec.get("prefix_embeds")
            audio_embeds = spec.get("audio_embeds")
            cfg = self.engine.cfg
            if prefix_embeds is not None:
                # shape-check here so a ragged payload is a 400, not a
                # dead driver task mid-asarray
                if not cfg.vision_prefix:
                    raise ValueError(f"{cfg.name} takes no prefix_embeds")
                if (len(prefix_embeds) != cfg.vision_prefix or any(
                        len(r) != cfg.d_model for r in prefix_embeds)):
                    raise ValueError(
                        f"prefix_embeds must be {cfg.vision_prefix} x "
                        f"{cfg.d_model}")
            if audio_embeds is not None:
                if cfg.family != "encdec":
                    raise ValueError(f"{cfg.name} takes no audio_embeds")
                if (len(audio_embeds) != cfg.encoder_seq or any(
                        len(r) != cfg.d_model for r in audio_embeds)):
                    raise ValueError(
                        f"audio_embeds must be {cfg.encoder_seq} x "
                        f"{cfg.d_model}")
        except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
            await _respond_json(writer, 400, {"error": f"bad request: {e}"})
            return
        if len(prompt) > self.engine.max_prompt_len \
                or not 1 <= max_new <= self.engine.max_new_tokens:
            await _respond_json(writer, 400, {
                "error": f"prompt_len <= {self.engine.max_prompt_len} and "
                         f"1 <= max_new_tokens <= "
                         f"{self.engine.max_new_tokens} required"})
            return

        report = self.engine.report
        # -- SLO-aware admission: bounded queue, deadline-checked ----------
        if len(self.queue) >= self.settings.queue_depth:
            report.rejected_429 += 1
            self.metrics.counter("frontdoor_rejected_429_total").inc()
            await _respond_json(writer, 429, {
                "error": f"admission queue full "
                         f"({self.settings.queue_depth} pending)"})
            return
        if deadline_s is not None and deadline_s <= 0:
            report.rejected_408 += 1
            self.metrics.counter("frontdoor_rejected_408_total").inc()
            await _respond_json(writer, 408, {"error": "deadline expired"})
            return
        rid = next(self._rids)
        req = Request(rid=rid, prompt=list(prompt), max_new_tokens=max_new,
                      deadline_s=deadline_s, priority=priority,
                      prefix_embeds=prefix_embeds, audio_embeds=audio_embeds)
        entry = _Pending(req, None if deadline_s is None
                         else time.perf_counter() + deadline_s)
        self.queue.append(entry)
        self.metrics.counter("frontdoor_admitted_total").inc()
        self.metrics.gauge("frontdoor_queue_depth").set(len(self.queue))
        report.peak_queue_depth = max(report.peak_queue_depth,
                                      len(self.queue))

        # status line waits for the queue verdict: 408 must be a real 408,
        # not a half-started event stream
        outcome = await entry.gate
        if outcome == "expired":
            await _respond_json(writer, 408, {
                "error": "deadline expired in queue"})
            return
        if outcome == "cancelled":
            return

        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        watch = asyncio.create_task(_watch_eof(reader))
        n = 0
        try:
            while True:
                getter = asyncio.create_task(entry.events.get())
                done, _ = await asyncio.wait(
                    {getter, watch}, return_when=asyncio.FIRST_COMPLETED)
                if getter not in done:          # client went away first
                    getter.cancel()
                    self._cancels.add(rid)
                    return
                kind, payload = getter.result()
                if kind == "tokens":
                    n += len(payload)
                    writer.write(_sse({"rid": rid, "tokens": payload}))
                    await writer.drain()
                elif kind == "done":
                    writer.write(b"event: done\r\ndata: " +
                                 json.dumps({"rid": rid, "n": n}).encode() +
                                 b"\r\n\r\n")
                    await writer.drain()
                    return
                else:                           # cancelled server-side
                    return
        except (ConnectionResetError, BrokenPipeError, OSError):
            self._cancels.add(rid)              # mid-stream disconnect
        finally:
            watch.cancel()


# -- wire helpers -----------------------------------------------------------

_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           408: "Request Timeout", 429: "Too Many Requests"}


async def _read_head(reader):
    """Parse request line + headers (no pipelining; one request/conn)."""
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) < 3:
        return None, None, None
    headers = {}
    for line in lines[1:]:
        if ":" in line:
            k, v = line.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    return parts[0].upper(), parts[1], headers


async def _respond(writer, status: int, body: str, *,
                   ctype: str = "text/plain") -> None:
    data = body.encode()
    writer.write((f"HTTP/1.1 {status} {_STATUS.get(status, '')}\r\n"
                  f"Content-Type: {ctype}\r\n"
                  f"Content-Length: {len(data)}\r\n"
                  f"Connection: close\r\n\r\n").encode() + data)
    await writer.drain()


async def _respond_json(writer, status: int, obj: dict) -> None:
    await _respond(writer, status, json.dumps(obj),
                   ctype="application/json")


def _sse(obj: dict) -> bytes:
    return b"data: " + json.dumps(obj).encode() + b"\r\n\r\n"


async def _watch_eof(reader) -> None:
    """Resolve when the client half closes (disconnect detection while the
    server is the only side writing)."""
    try:
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                return
    except (ConnectionResetError, OSError):
        return


def sse_decode_tokens(payload: bytes) -> List[int]:
    """Client-side helper (tests, benches, the serve CLI's HTTP mode):
    concatenate the ``tokens`` arrays out of a raw SSE response body."""
    toks: List[int] = []
    for block in payload.split(b"\r\n\r\n"):
        for line in block.split(b"\r\n"):
            if line.startswith(b"data: "):
                try:
                    obj = json.loads(line[len(b"data: "):])
                except json.JSONDecodeError:
                    continue
                if isinstance(obj, dict) and "tokens" in obj:
                    toks.extend(obj["tokens"])
    return toks
