"""jit-able train / prefill / serve steps with explicit shardings.

``make_train_step`` supports microbatch gradient accumulation (scan) — with
per-layer remat this is what bounds activation memory for the 405B cell —
and bf16 gradient all-reduce (compression) with fp32 update math.

All step functions take ``(params, [opt_state,] inputs: dict)`` so one
sharding pytree covers the whole input bundle uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, cosine_schedule
from repro.runtime import sharding as shd


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1
    fsdp: bool = False
    fsdp_serve: bool = False
    opt_dtype: Any = jnp.float32
    grad_dtype: Any = jnp.bfloat16      # gradient compression for the
                                        # cross-pod all-reduce
    zero2: bool = False                 # gather FSDP weights ONCE per step
                                        # (not per microbatch): 8-16× less
                                        # all-gather traffic, costs one
                                        # model-sharded weight copy in HBM.
                                        # Off for 405B-class (copy too big).


def _split_micro(batch, n):
    def f(x):
        B = x.shape[0]
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    settings: TrainSettings, *,
                    gathered_shardings=None, fsdp_shardings=None):
    """train_step(params, opt_state, inputs) → (params, opt_state, metrics).

    inputs = {"batch": {tokens, labels, [embeds]}, "step": scalar}

    With ``settings.zero2`` and the two sharding pytrees provided, weights
    are all-gathered from their FSDP shards ONCE per step (constrained to
    ``gathered_shardings``), reused across every microbatch, and gradients
    are reduce-scattered back to ``fsdp_shardings`` before the optimizer —
    ZeRO-2 semantics instead of ZeRO-3's per-microbatch regather.
    """

    def loss_of(params, mb):
        return T.loss_fn(params, cfg, mb)

    def train_step(params, opt_state, inputs):
        batch, step = inputs["batch"], inputs["step"]
        n = settings.microbatches
        opt_params = params
        if settings.zero2 and gathered_shardings is not None:
            params = jax.lax.with_sharding_constraint(
                params, gathered_shardings)
        if n > 1:
            micro = _split_micro(batch, n)

            def acc_fn(carry, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                g = jax.tree.map(lambda a: a.astype(settings.grad_dtype), g)
                if fsdp_shardings is not None:
                    # reduce-scatter each microbatch's gradients onto the
                    # ZeRO shards immediately: the accumulator stays sharded
                    # (vs. an all-reduce leaving grads replicated over data)
                    g = jax.lax.with_sharding_constraint(g, fsdp_shardings)
                carry_l, carry_g = carry
                return (carry_l + l,
                        jax.tree.map(jnp.add, carry_g, g)), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, settings.grad_dtype), params)
            if fsdp_shardings is not None:
                g0 = jax.lax.with_sharding_constraint(g0, fsdp_shardings)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
            grads = jax.tree.map(
                lambda a: a.astype(settings.grad_dtype), grads)

        if settings.zero2 and fsdp_shardings is not None:
            # reduce-scatter gradients back onto the ZeRO shards
            grads = jax.lax.with_sharding_constraint(grads, fsdp_shardings)
        lr_scale = cosine_schedule(step)
        new_params, opt_state, om = adamw_update(
            grads, opt_state, opt_params, opt_cfg, lr_scale)
        metrics = {"loss": loss, **om}
        return new_params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    """prefill_step(params, inputs={tokens, [prefix_embeds], [audio_embeds]})."""
    def prefill_step(params, inputs):
        return T.prefill(params, cfg, inputs["tokens"], cache_len=cache_len,
                         prefix_embeds=inputs.get("prefix_embeds"),
                         audio_embeds=inputs.get("audio_embeds"))
    return prefill_step


def make_serve_step(cfg: ModelConfig, *, cache_len: int = 0,
                    kv_format: str = "kv_fp16",
                    attn_path: str = "gather", kv_partitions=None,
                    live_pages=None):
    """serve_step(params, inputs={state, tokens, pos, [tables], [active]})
    — one decode step. When ``inputs`` carries per-slot block ``tables``
    the KV state is the paged pool, ``cache_len``/``kv_format`` select the
    slot-window length and KV storage format, and ``attn_path`` /
    ``kv_partitions`` the planned decode-attention path and Split-K
    degree (see runtime/kvcache.py). ``live_pages`` (static) clamps the
    gather path to the batch's live-page high-water mark — the engine
    compiles one variant per power-of-2 bucket. ``active`` (B,) bool
    masks recurrent-carry writes for rows that are mid chunked prefill
    (carry families on the chunked engine only)."""
    def serve_step(params, inputs):
        logits, state = T.decode_step(
            params, cfg, inputs["state"], inputs["tokens"], inputs["pos"],
            tables=inputs.get("tables"), active=inputs.get("active"),
            cache_len=cache_len, kv_format=kv_format, attn_path=attn_path,
            kv_partitions=kv_partitions, live_pages=live_pages)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return {"next": next_tok, "logits": logits, "state": state}
    return serve_step


def make_prefill_chunk_step(cfg: ModelConfig, cache_len: int, *,
                            kv_format: str = "kv_fp16",
                            attn_path: str = "gather", kv_partitions=None,
                            live_pages=None):
    """chunk_step(params, state, inputs={h, positions, slot, [table]}) —
    one chunked-prefill step for one slot (see T.prefill_chunk_step):
    attends the slot's pooled window on ``attn_path`` (gather, clamped to
    ``live_pages``, or the fused multi-query kernel with ``kv_partitions``
    page-axis splits), scatters the chunk's K/V into the slot's pooled
    pages (attention families — ``table`` absent for attention-free
    rwkv), threads the slot's recurrent carries / cross-KV through by the
    ``slot`` row index, and returns the updated state plus
    last-valid-position logits (used when the final chunk completes the
    prompt). ``state`` is its own argument so the block pool — the
    largest serving tensor — can be donated without dragging the small
    non-donatable chunk inputs along."""
    def chunk_step(params, state, inputs):
        logits, state = T.prefill_chunk_step(
            params, cfg, state, inputs["h"], inputs["positions"],
            inputs.get("table"), inputs["slot"],
            cache_len=cache_len, kv_format=kv_format, attn_path=attn_path,
            kv_partitions=kv_partitions, live_pages=live_pages)
        return {"logits": logits, "state": state}
    return chunk_step


def make_verify_step(cfg: ModelConfig, cache_len: int, *,
                     kv_format: str = "kv_fp16",
                     attn_path: str = "gather", kv_partitions=None,
                     live_pages=None):
    """verify(params, state, inputs={tokens, positions, [tables]}) — one
    batched speculative-verify step (see T.verify_step): scores the last
    emitted token plus up to C-1 draft tokens for every slot in one
    forward pass and returns the per-position greedy choice. ``next`` is
    the device-side argmax over *all* (slot, position) cells, so the host
    syncs one (B, C) int array per step regardless of batch or draft
    length. The (B, k+1) window attends its pooled context on
    ``attn_path`` exactly like a prefill chunk (``"fused"`` = one
    multi-query kernel pass, ``"gather"`` clamped to ``live_pages``).
    ``state`` is its own (donatable) argument, as in the chunked
    prefill step. Carry families additionally return ``carries`` — the
    per-position carry checkpoints the engine selects the accepted
    frontier from (see T.verify_step)."""
    def verify(params, state, inputs):
        logits, state, carries = T.verify_step(
            params, cfg, state, inputs["tokens"], inputs["positions"],
            inputs.get("tables"), cache_len=cache_len, kv_format=kv_format,
            attn_path=attn_path, kv_partitions=kv_partitions,
            live_pages=live_pages)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = {"next": next_tok, "logits": logits, "state": state}
        if carries is not None:
            out["carries"] = carries
        return out
    return verify


# ---------------------------------------------------------------------------
# sharding builders for the input bundles
# ---------------------------------------------------------------------------

def train_input_shardings(inputs_abstract, mesh):
    rep = NamedSharding(mesh, P())
    return {
        "batch": shd.data_shardings(inputs_abstract["batch"], mesh),
        "step": rep,
    }


def prefill_input_shardings(inputs_abstract, mesh):
    return shd.data_shardings(inputs_abstract, mesh)


def serve_input_shardings(inputs_abstract, cfg, mesh):
    out = {
        "state": shd.decode_state_shardings(inputs_abstract["state"], cfg, mesh),
        "tokens": shd.data_shardings(inputs_abstract["tokens"], mesh),
        "pos": shd.data_shardings(inputs_abstract["pos"], mesh),
    }
    if "tables" in inputs_abstract:       # paged: (B, pages_per_slot)
        out["tables"] = shd.data_shardings(inputs_abstract["tables"], mesh)
    if "active" in inputs_abstract:       # carry families, chunked engine
        out["active"] = shd.data_shardings(inputs_abstract["active"], mesh)
    return out


# ---------------------------------------------------------------------------
# sharded jit wrappers (what dryrun.py lowers)
# ---------------------------------------------------------------------------

def jit_train_step(cfg, mesh, settings: TrainSettings, params_abstract,
                   inputs_abstract, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=settings.opt_dtype)
    pshard = shd.param_shardings(params_abstract, mesh, fsdp=settings.fsdp)
    gathered = None
    if settings.zero2 and settings.fsdp:
        gathered = shd.param_shardings(params_abstract, mesh, fsdp=False)
    step_fn = make_train_step(
        cfg, opt_cfg, settings,
        gathered_shardings=gathered,
        fsdp_shardings=pshard if settings.fsdp else None)
    rep = NamedSharding(mesh, P())
    oshard = {"m": pshard, "v": pshard, "count": rep}
    ishard = train_input_shardings(inputs_abstract, mesh)
    mshard = {"loss": rep, "grad_norm": rep}
    return jax.jit(
        step_fn,
        in_shardings=(pshard, oshard, ishard),
        out_shardings=(pshard, oshard, mshard),
        donate_argnums=(0, 1),
    )


def jit_prefill_step(cfg, mesh, cache_len: int, params_abstract,
                     inputs_abstract, *, fsdp_serve=False):
    fn = make_prefill_step(cfg, cache_len)
    pshard = shd.param_shardings(params_abstract, mesh, fsdp=fsdp_serve)
    ishard = prefill_input_shardings(inputs_abstract, mesh)
    # constrain the RETURNED decode state too — without this the prefilled
    # KV cache materializes replicated (catastrophic at 32k×405B)
    _, state_abs = jax.eval_shape(fn, params_abstract, inputs_abstract)
    sshard = shd.decode_state_shardings(state_abs, cfg, mesh)
    B = inputs_abstract["tokens"].shape[0]
    # same normalized entry as the input shardings (shd.batch_axis_entry) —
    # a raw bspec[0] here could disagree with data_shardings on older jax
    baxis = shd.batch_axis_entry(B, mesh)
    return jax.jit(
        fn,
        in_shardings=(pshard, ishard),
        out_shardings=(NamedSharding(mesh, P(baxis, None)), sshard),
    )


def jit_serve_step(cfg, mesh, params_abstract, inputs_abstract, *,
                   fsdp_serve=False, cache_len: int = 0,
                   kv_format: str = "kv_fp16", attn_path: str = "gather",
                   kv_partitions=None, live_pages=None):
    fn = make_serve_step(cfg, cache_len=cache_len, kv_format=kv_format,
                         attn_path=attn_path, kv_partitions=kv_partitions,
                         live_pages=live_pages)
    pshard = shd.param_shardings(params_abstract, mesh, fsdp=fsdp_serve)
    ishard = serve_input_shardings(inputs_abstract, cfg, mesh)
    B = inputs_abstract["tokens"].shape[0]
    baxis = shd.batch_axis_entry(B, mesh)
    return jax.jit(
        fn,
        in_shardings=(pshard, ishard),
        out_shardings={
            "next": NamedSharding(mesh, P(baxis)),
            "logits": NamedSharding(mesh, P(baxis, None)),
            "state": ishard["state"],
        },
        donate_argnums=(1,),
    )


def jit_prefill_chunk_step(cfg, mesh, cache_len, params_abstract,
                           inputs_abstract, *, kv_format: str = "kv_fp16",
                           attn_path: str = "gather", kv_partitions=None,
                           live_pages=None, fsdp_serve=False):
    """Sharded chunked-prefill step: state in/out on the decode-state
    shardings (the pool replicates pages over DP, shards heads over TP);
    the B=1 chunk inputs replicate."""
    fn = make_prefill_chunk_step(cfg, cache_len, kv_format=kv_format,
                                 attn_path=attn_path,
                                 kv_partitions=kv_partitions,
                                 live_pages=live_pages)
    pshard = shd.param_shardings(params_abstract, mesh, fsdp=fsdp_serve)
    sshard = shd.decode_state_shardings(inputs_abstract["state"], cfg, mesh)
    ishard = {k: shd.data_shardings(v, mesh)
              for k, v in inputs_abstract.items() if k != "state"}
    return jax.jit(
        fn,
        in_shardings=(pshard, sshard, ishard),
        out_shardings={
            "logits": NamedSharding(mesh, P(None, None)),
            "state": sshard,
        },
        # donate the state: the block pool is the largest serving tensor
        # and would otherwise be copied whole on every prefill chunk
        donate_argnums=(1,),
    )


def jit_verify_step(cfg, mesh, cache_len, params_abstract,
                    inputs_abstract, *, kv_format: str = "kv_fp16",
                    attn_path: str = "gather", kv_partitions=None,
                    live_pages=None, fsdp_serve=False):
    """Sharded speculative-verify step: state in/out on the decode-state
    shardings (donated, like the chunk step); tokens/positions/tables are
    batch-sharded over data, and the (B, C) next/logits outputs come back
    batch-sharded too."""
    fn = make_verify_step(cfg, cache_len, kv_format=kv_format,
                          attn_path=attn_path, kv_partitions=kv_partitions,
                          live_pages=live_pages)
    pshard = shd.param_shardings(params_abstract, mesh, fsdp=fsdp_serve)
    sshard = shd.decode_state_shardings(inputs_abstract["state"], cfg, mesh)
    ishard = {k: shd.data_shardings(v, mesh)
              for k, v in inputs_abstract.items() if k != "state"}
    B = inputs_abstract["tokens"].shape[0]
    baxis = shd.batch_axis_entry(B, mesh)
    oshard = {
        "next": NamedSharding(mesh, P(baxis, None)),
        "logits": NamedSharding(mesh, P(baxis, None, None)),
        "state": sshard,
    }
    if cfg.family in T.CARRY_FAMILIES:
        # carries are (L, B, C+1, ...) checkpoint stacks — batch on axis 1
        out_abs = jax.eval_shape(
            fn, params_abstract, inputs_abstract["state"], ishard_inputs(
                inputs_abstract))

        def cshard(leaf):
            spec = [None] * leaf.ndim
            spec[1] = baxis
            return NamedSharding(mesh, P(*spec))

        oshard["carries"] = jax.tree.map(cshard, out_abs["carries"])
    return jax.jit(
        fn,
        in_shardings=(pshard, sshard, ishard),
        out_shardings=oshard,
        donate_argnums=(1,),
    )


def ishard_inputs(inputs_abstract):
    """The non-state portion of a (params, state, inputs) step's bundle."""
    return {k: v for k, v in inputs_abstract.items() if k != "state"}
