"""Speculative decoding proposers for the paged serving engine.

The paper's decode profile is weight-traffic-bound (K >> N GEMMs at M=1
fetch the whole weight matrix per generated token); scoring k draft
tokens in ONE forward pass multiplies tokens-per-weight-fetch, which is
why ROADMAP calls speculation the biggest tokens/sec lever for this
stack. This module supplies the *proposal* side; the engine owns the
batched verify step (``steps.make_verify_step``), exact greedy
acceptance, and allocator-level rollback.

Two proposers:

  :class:`NgramProposer`       — self-speculation by prompt lookup: the
      longest recent n-gram match of the slot's context suffix proposes
      the tokens that followed it. No second model, no extra state —
      free wins on repetitive prompts/outputs.
  :class:`DraftModelProposer`  — a small draft model built through the
      same :class:`~repro.models.config.ModelConfig` machinery, decoding
      ahead on a pooled (non-paged) ring state. The draft is fed the
      *accepted* tokens between rounds (catch-up), so its cache always
      agrees with the target's committed stream.

The contract that keeps verification exact: proposers only ever
*suggest* tokens. The engine scores suggestion j against the target's
own greedy choice at the previous position and accepts the longest
matching prefix — so emitted text is token-identical to non-speculative
decode no matter how wrong a proposer is.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import serve_cache_len
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime import steps as rsteps

__all__ = [
    "Proposer", "ProposalView", "NgramProposer", "DraftModelProposer",
    "PROPOSERS", "available_proposers", "validate_speculate",
    "make_proposer",
]


class ProposalView(NamedTuple):
    """What a proposer sees of one active slot at propose time."""

    slot: int             # batch slot index
    context: List[int]    # prompt + emitted token ids (committed stream)
    pos_next: int         # target's next decode position


class Proposer:
    """Draft-token source for speculative decoding.

    Lifecycle (driven by :class:`~repro.runtime.engine.ServingEngine`):
    ``reset`` once per :meth:`run`, ``admit``/``evict`` as slots turn
    over, ``propose`` once per decode step for every active slot.
    Proposals are pure suggestions — length 0..k per slot, clamped and
    verified by the engine — so implementations never need to know about
    pages, wrap limits, or remaining-token budgets.
    """

    name = "base"

    def reset(self, engine) -> None:                 # noqa: D401
        pass

    def admit(self, engine, i: int, slot) -> None:
        pass

    def evict(self, engine, i: int) -> None:
        pass

    def propose(self, views: Sequence[ProposalView], k: int
                ) -> Dict[int, List[int]]:
        raise NotImplementedError


class NgramProposer(Proposer):
    """Prompt-lookup self-speculation (no draft model).

    For each slot, match the longest context suffix of length
    ``max_n..1`` against earlier context and propose the (up to) k
    tokens that followed the most recent match. Proposes nothing when no
    n-gram recurs — speculation then degrades to plain decode for that
    slot, costing one extra scored position.
    """

    name = "ngram"

    def __init__(self, max_n: int = 3):
        if max_n < 1:
            raise ValueError(f"ngram max_n must be >= 1, got {max_n}")
        self.max_n = int(max_n)

    def propose(self, views, k):
        out: Dict[int, List[int]] = {}
        for view in views:
            ctx = view.context
            L = len(ctx)
            props: List[int] = []
            for n in range(min(self.max_n, L - 1), 0, -1):
                pat = ctx[L - n:]
                for j in range(L - n - 1, -1, -1):
                    if ctx[j:j + n] == pat:
                        props = ctx[j + n:j + n + k]
                        break
                if props:
                    break
            if props:
                out[view.slot] = props
        return out


class DraftModelProposer(Proposer):
    """Draft-model speculation: a small model decodes k tokens ahead.

    The draft holds a pooled ring decode state (one row per engine slot,
    the pre-paged layout — the draft never pages). Between rounds it is
    *caught up* by feeding the accepted real tokens for every position
    from its frontier to the target's, then chained on its own argmax
    for the k proposals. Slots whose chain finished early idempotently
    re-feed their last (token, position) — a same-slot ring overwrite
    with identical content — which keeps the per-step batch dense.

    Recurrent carry families (``T.CARRY_FAMILIES``) are refused: the
    re-feed/rewind discipline relies on cache writes being keyed by
    position, and a draft's own wkv/ssm state mutation is not idempotent
    (the *target* side handles carries via verify-step checkpoints, but
    the draft decodes token by token with no checkpoint to rewind to).
    """

    name = "draft"

    def __init__(self, cfg: ModelConfig, params=None, *, seed: int = 1):
        if cfg.family in T.CARRY_FAMILIES:
            raise ValueError(
                f"draft speculation cannot use a {cfg.family!r} draft — "
                f"recurrent carry families {T.CARRY_FAMILIES} cannot "
                f"rewind rejected drafts (cache writes must be keyed by "
                f"position); use an attention-state draft or ngram")
        self.cfg = cfg
        if params is None:
            params = T.init_params(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self.state = None
        self._step_fn = None
        self._prefill_fns: Dict[tuple, object] = {}

    # -- lifecycle ---------------------------------------------------------

    def reset(self, engine) -> None:
        cfg = self.cfg
        if cfg.vision_prefix != (engine.cfg.vision_prefix or 0) or (
                cfg.vision_prefix and cfg.d_model != engine.cfg.d_model):
            raise ValueError(
                f"draft cfg must match the target's vision frontend "
                f"(vision_prefix {cfg.vision_prefix} vs "
                f"{engine.cfg.vision_prefix}, d_model {cfg.d_model} vs "
                f"{engine.cfg.d_model}) — prefix embeds feed both models")
        self.B = engine.max_batch
        self.voff = cfg.vision_prefix or 0
        # ring window: the full committed stream plus one chained draft
        # overhang; min-window clamping (SWA) wraps exactly like target
        # decode does
        self.cache_len = serve_cache_len(
            cfg, engine.max_prompt_len,
            engine.max_new_tokens + engine.spec_k + 1)
        self.state = T.init_decode_state(cfg, self.B, self.cache_len)
        if self._step_fn is None:
            self._step_fn = jax.jit(rsteps.make_serve_step(cfg))
        self.dpos = np.zeros(self.B, np.int64)     # next unfed position
        self.last_tok = np.zeros(self.B, np.int64)
        self.last_pos = np.zeros(self.B, np.int64)

    def _prefill(self, inputs):
        key = tuple(sorted((k, v.shape) for k, v in inputs.items()))
        fn = self._prefill_fns.get(key)
        if fn is None:
            fn = jax.jit(rsteps.make_prefill_step(self.cfg, self.cache_len))
            self._prefill_fns[key] = fn
        return fn

    def admit(self, engine, i: int, slot) -> None:
        from repro.runtime.engine import insert_slot
        req = slot.req
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        inputs = {"tokens": prompt}
        if self.cfg.vision_prefix:
            pe = req.prefix_embeds
            if pe is None:
                pe = jnp.zeros((self.cfg.vision_prefix, self.cfg.d_model),
                               self.cfg.dtype)
            inputs["prefix_embeds"] = jnp.asarray(pe, self.cfg.dtype)[None]
        _, rstate = self._prefill(inputs)(self.params, inputs)
        self.state = insert_slot(self.state, rstate, i)
        pos0 = len(req.prompt) + self.voff
        self.dpos[i] = pos0
        self.last_tok[i] = int(np.asarray(req.prompt).reshape(-1)[-1])
        self.last_pos[i] = pos0 - 1

    def evict(self, engine, i: int) -> None:
        from repro.runtime.engine import reset_slot
        self.state = reset_slot(self.state, i)
        self.dpos[i] = 0
        self.last_tok[i] = 0
        self.last_pos[i] = 0

    # -- proposal ----------------------------------------------------------

    def propose(self, views, k):
        if not views:
            return {}
        # per-slot feed schedules: real catch-up tokens first (rewound to
        # the committed frontier — stale speculative ring entries are
        # overwritten position by position before anything queries them),
        # then k-1 chained self-feeds
        feeds: Dict[int, List[tuple]] = {}
        chain_left: Dict[int, int] = {}
        for view in views:
            i, ctx, pos_next = view.slot, view.context, view.pos_next
            start = min(int(self.dpos[i]), pos_next)
            feeds[i] = [(ctx[q - self.voff], q)
                        for q in range(start, pos_next + 1)]
            chain_left[i] = k - 1
        out: Dict[int, List[int]] = {v.slot: [] for v in views}
        n_steps = max(len(feeds[i]) + chain_left[i] for i in feeds)
        collecting: Dict[int, bool] = {}
        for t in range(n_steps):
            tok = self.last_tok.copy()
            pos = self.last_pos.copy()
            for i, sched in feeds.items():
                if t < len(sched):
                    tok[i], pos[i] = sched[t]
                    collecting[i] = (t == len(sched) - 1)
                elif t < len(sched) + chain_left[i]:
                    tok[i] = out[i][-1]           # chain on own argmax
                    pos[i] = pos[i] + 1           # ... one position ahead
                    collecting[i] = True
                else:
                    collecting[i] = False
            res = self._step_fn(self.params, {
                "state": self.state,
                "tokens": jnp.asarray(tok, jnp.int32),
                "pos": jnp.asarray(pos, jnp.int32),
            })
            self.state = res["state"]
            nxt = np.asarray(res["next"])
            self.last_tok, self.last_pos = tok, pos
            for i in feeds:
                if collecting.get(i):
                    out[i].append(int(nxt[i]))
        for view in views:
            self.dpos[view.slot] = view.pos_next + k
        return out


# ---------------------------------------------------------------------------
# registry + validation (the launcher's up-front refusal path)
# ---------------------------------------------------------------------------

PROPOSERS = {"ngram": NgramProposer, "draft": DraftModelProposer}


def available_proposers() -> List[str]:
    return sorted(PROPOSERS)


def validate_speculate(speculate: Optional[str], spec_k: int, *,
                       cfg: ModelConfig, paged: bool = True
                       ) -> Optional[str]:
    """Resolve/validate ``--speculate`` × ``--spec-k`` up front.

    Mirrors the planner's (and ``--kv-format``'s) forced-pair refusal: a
    bad combination fails here with the registry's vocabulary instead of
    deep inside the serving loop. Returns the proposer name (the part
    before ``:``), or None when speculation is off.
    """
    if speculate in (None, "", "off"):
        return None
    name = str(speculate).split(":", 1)[0]
    if name not in PROPOSERS:
        raise ValueError(
            f"--speculate {speculate!r}: unknown proposer {name!r}. "
            f"Registered proposers: {available_proposers()} "
            f"(use 'draft:<spec>' to derive a draft model)")
    if spec_k < 1:
        raise ValueError(
            f"--spec-k must be >= 1 (got {spec_k}); speculation scores "
            f"the last emitted token plus spec_k drafts per step")
    if not paged:
        raise ValueError(
            f"--speculate {name!r} requires the paged/chunked engine "
            f"(rollback is allocator-level and verify checkpoints carries "
            f"through the chunked path); drop --ring")
    if cfg.sliding_window and spec_k >= cfg.sliding_window:
        raise ValueError(
            f"--spec-k {spec_k} must be smaller than the sliding window "
            f"({cfg.sliding_window}): a draft overhang spanning the whole "
            f"window would evict entries its own verify still attends")
    return name


def make_proposer(speculate: str, *, target_cfg: ModelConfig,
                  draft_cfg: Optional[ModelConfig] = None,
                  draft_params=None, seed: int = 1) -> Proposer:
    """Build a proposer from a ``--speculate`` spec string.

    ``ngram`` / ``ngram:<max_n>`` — prompt lookup; ``draft`` /
    ``draft:layers=<N>`` — a draft model derived from the target config
    with ``N`` layers (default 1), or exactly ``draft_cfg``/``draft_params``
    when the caller supplies them.
    """
    name, _, arg = str(speculate).partition(":")
    if name == "ngram":
        return NgramProposer(int(arg)) if arg else NgramProposer()
    if name == "draft":
        cfg = draft_cfg
        if cfg is None:
            n_layers = 1
            if arg:
                key, _, val = arg.partition("=")
                if key != "layers" or not val.isdigit():
                    raise ValueError(
                        f"--speculate draft:{arg!r}: expected "
                        f"'draft:layers=<N>' (or pass a draft config "
                        f"programmatically)")
                n_layers = int(val)
            cfg = dataclasses.replace(target_cfg, num_layers=n_layers,
                                      w4a16_plan=None)
        return DraftModelProposer(cfg, draft_params, seed=seed)
    raise ValueError(f"unknown proposer {name!r}; registered: "
                     f"{available_proposers()}")
