"""Fault-tolerant training runner: checkpoint cadence, retry, elastic re-mesh.

The failure model at 1000+ nodes:
  * transient step failure (preempted host, flaky ICI link, data glitch) —
    retried up to ``max_retries`` from the in-memory state;
  * hard failure (lost slice) — the runner restores the latest checkpoint
    and, if the caller provides ``remesh_fn``, re-lowers the step on a
    degraded mesh (elastic rescale) before continuing;
  * straggler mitigation — steps are bounded by ``step_timeout_s``; a
    timeout is treated as a transient failure (the sync collectives make a
    straggler indistinguishable from a hang at this layer). On real fleets
    this hooks the host watchdog; here it is wall-clock based.

``inject_failure`` lets tests script failures at chosen steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries: int = 3
    step_timeout_s: float = 3600.0
    keep_last: int = 3


class StepFailure(RuntimeError):
    pass


def _gc_checkpoints(ckpt_dir: str, keep: int):
    import os, re, shutil
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(m.group(1)) for n in os.listdir(ckpt_dir)
                   if (m := re.match(r"^step_(\d+)$", n)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def run_training(
    *,
    cfg: RunnerConfig,
    train_step: Callable,                    # (params, opt, inputs) -> ...
    params: Any,
    opt_state: Any,
    batches: Callable[[int], dict],          # step -> inputs dict
    num_steps: int,
    inject_failure: Optional[Callable[[int, int], bool]] = None,
    remesh_fn: Optional[Callable[[], Callable]] = None,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
):
    """Run ``num_steps`` with checkpoint/restart semantics.

    Returns (params, opt_state, history) where history records every
    recovery event — the fault-tolerance audit trail.
    """
    history = []
    start = latest_step(cfg.ckpt_dir)
    step = 0
    if start is not None:
        restored, step0, _ = restore_checkpoint(
            cfg.ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        step = step0 + 1
        history.append(("resume", step))

    retries = 0
    while step < num_steps:
        inputs = batches(step)
        t0 = time.time()
        try:
            if inject_failure is not None and inject_failure(step, retries):
                raise StepFailure(f"injected failure at step {step}")
            params2, opt2, metrics = train_step(params, opt_state, inputs)
            jax.block_until_ready(metrics)
            if time.time() - t0 > cfg.step_timeout_s:
                raise StepFailure(f"straggler timeout at step {step}")
        except Exception as e:  # noqa: BLE001 — any failure is retried
            retries += 1
            history.append(("failure", step, str(e)[:120]))
            if retries > cfg.max_retries:
                # hard failure: restore + optionally re-mesh (elastic)
                restored, step0, _ = restore_checkpoint(
                    cfg.ckpt_dir, {"params": params, "opt": opt_state})
                if restored is not None:
                    params, opt_state = restored["params"], restored["opt"]
                    step = step0 + 1
                if remesh_fn is not None:
                    train_step = remesh_fn()
                    history.append(("remesh", step))
                retries = 0
                history.append(("restart", step))
            continue

        params, opt_state = params2, opt2
        retries = 0
        if on_metrics is not None:
            on_metrics(step, jax.tree.map(float, metrics))
        if step % cfg.ckpt_every == 0 or step == num_steps - 1:
            save_checkpoint(cfg.ckpt_dir, step,
                            {"params": params, "opt": opt_state})
            _gc_checkpoints(cfg.ckpt_dir, cfg.keep_last)
            history.append(("checkpoint", step))
        step += 1
    return params, opt_state, history
