"""Paged, prefix-shared KV cache: block pool + ref-counted allocator.

The decode step is memory-bandwidth-bound (the paper's K ≫ N regime caps at
1.48x because of weight bytes); at serving scale the KV cache is the other
tensor whose HBM footprint and traffic decide throughput. This module
replaces the per-slot contiguous ring caches with a **block pool**:

  device side  — :class:`PagedKVCache`: ``k_pool``/``v_pool`` of
                 ``num_blocks × page_size × Hkv × D`` (per layer; the model
                 stacks an L axis on top) plus per-slot ``page_pos`` tags
                 and optional ``kv8_channel`` scales. Gather/scatter run
                 through per-slot **block tables** ``(B, pages_per_slot)``.
  host side    — :class:`BlockAllocator`: ref-counted alloc/free driven by
                 the engine's admit/evict scheduler, with a chain-hash
                 prefix index so identical prompt prefixes across slots map
                 to the *same* physical blocks (copy-on-write at the first
                 divergent write).

Layout invariant (what makes paged decode token-identical to the ring):
a slot's logical window is ``cache_len`` entries (rounded up to a page
multiple — see ``configs.shapes.serve_cache_len``), and a token at absolute
position ``p`` lives at logical offset ``p % cache_len``, i.e. page
``offset // page_size`` slot ``offset % page_size`` of the slot's table.
Gathering a table therefore reconstructs *exactly* the ring buffer the
pre-paged engine kept per slot — same entries, same order, same pos-tag
masking — so ``attention.decode_attention`` runs unchanged on the gathered
window and SWA/vision-prefix semantics carry over verbatim.

Physical block 0 is reserved as the permanently-empty **null block**: table
entries of ``-1`` gather it (all ``pos`` tags ``-1`` → fully masked), and
writes from inactive slots are redirected into it with ``-1`` tags so they
can never materialize a valid entry.
"""
from __future__ import annotations

import collections
import hashlib
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import (
    DEFAULT_KV_FORMAT, KVFormat, get_kv_format, kv_dequantize, kv_quantize,
)
from repro.models import attention

__all__ = [
    "PagedKVCache", "BlockAllocator", "NULL_BLOCK",
    "init_pool", "pages_per_slot", "paged_insert", "paged_decode_attention",
    "gather_window", "scatter_chunk", "scatter_chunks", "scatter_ring",
    "copy_blocks",
    "reset_blocks", "position_units", "page_keys",
]

NULL_BLOCK = 0


class PagedKVCache(NamedTuple):
    """Block-pool KV cache (one layer; the model stacks L in front).

    ``k_pool``/``v_pool``: (num_blocks, page_size, Hkv, D) — cache dtype for
    ``kv_fp16``, int8 for ``kv8_channel`` with per-(token, head) fp32
    scales in ``k_scale``/``v_scale`` (num_blocks, page_size, Hkv).
    ``page_pos``: (num_blocks, page_size) int32 absolute positions, -1 empty
    — the same validity tags ``attention.KVCache`` masks on.
    """

    k_pool: jax.Array
    v_pool: jax.Array
    page_pos: jax.Array
    k_scale: Optional[jax.Array] = None
    v_scale: Optional[jax.Array] = None

    @property
    def num_blocks(self) -> int:
        return self.page_pos.shape[-2]

    @property
    def page_size(self) -> int:
        return self.page_pos.shape[-1]


def init_pool(num_blocks: int, page_size: int, num_kv_heads: int,
              head_dim: int, dtype, kv_format: str = DEFAULT_KV_FORMAT
              ) -> PagedKVCache:
    """Fresh pool; block 0 is the null block (never allocated)."""
    fmt = get_kv_format(kv_format)
    shape = (num_blocks, page_size, num_kv_heads, head_dim)
    payload_dtype = jnp.int8 if fmt.quantized else dtype
    scale = (jnp.zeros(shape[:-1], jnp.float32) if fmt.quantized else None)
    return PagedKVCache(
        k_pool=jnp.zeros(shape, payload_dtype),
        v_pool=jnp.zeros(shape, payload_dtype),
        page_pos=jnp.full((num_blocks, page_size), -1, jnp.int32),
        k_scale=scale,
        v_scale=None if scale is None else jnp.zeros(shape[:-1], jnp.float32),
    )


def pages_per_slot(cache_len: int, page_size: int) -> int:
    if cache_len % page_size:
        raise ValueError(
            f"cache_len {cache_len} must be a page multiple (page_size "
            f"{page_size}); round it with configs.shapes.serve_cache_len")
    return cache_len // page_size


# ---------------------------------------------------------------------------
# device ops: gather / scatter through block tables
# ---------------------------------------------------------------------------

def _flat(pool_leaf: jax.Array) -> jax.Array:
    """(nb, ps, ...) → (nb*ps, ...) flat token-slot view."""
    nb, ps = pool_leaf.shape[:2]
    return pool_leaf.reshape(nb * ps, *pool_leaf.shape[2:])


def _unflat(flat_leaf: jax.Array, nb: int, ps: int) -> jax.Array:
    return flat_leaf.reshape(nb, ps, *flat_leaf.shape[1:])


def gather_window(pool: PagedKVCache, tables: jax.Array, *,
                  fmt: KVFormat, out_dtype,
                  live_pages: Optional[int] = None) -> attention.KVCache:
    """Reassemble each slot's logical ring window from its block table.

    tables: (B, T) int32, -1 → null block. Returns a virtual
    :class:`attention.KVCache` (B, T*page_size, Hkv, D) in ``out_dtype`` —
    the exact array layout the ring cache kept, so ``decode_attention``'s
    pos-tag masking (and therefore SWA / vision-prefix semantics) applies
    unchanged.

    ``live_pages`` (static) clamps the gather to the leading that-many
    table entries: ring offsets fill pages front-to-back until the stream
    wraps, so a caller that knows the batch's live-page high-water mark
    (the engine tracks it per step) skips materializing the dead
    page-rounded tail of ``cache_len`` — the over-gather that made the
    fallback path look worse than it is early in every request's life.
    Masking is unchanged; callers must not clamp below the high-water
    mark (dropped pages would silently vanish from attention).
    """
    bt = jnp.where(tables < 0, NULL_BLOCK, tables)         # (B, T)
    if live_pages is not None:
        bt = bt[:, :max(1, min(int(live_pages), bt.shape[1]))]
    B, T = bt.shape
    ps = pool.page_size

    def take(leaf):                                        # (nb, ps, ...) →
        g = jnp.take(leaf, bt.reshape(-1), axis=0)         # (B*T, ps, ...)
        return g.reshape(B, T * ps, *leaf.shape[2:])

    if not fmt.quantized:
        # passthrough formats store the cache dtype directly: no dequant
        # pass, and no scale pools to gather (they are None anyway)
        k = take(pool.k_pool)
        v = take(pool.v_pool)
        if k.dtype != jnp.dtype(out_dtype):
            k = k.astype(out_dtype)
            v = v.astype(out_dtype)
        return attention.KVCache(k=k, v=v, pos=take(pool.page_pos))
    k = kv_dequantize(take(pool.k_pool),
                      None if pool.k_scale is None else take(pool.k_scale),
                      fmt, out_dtype)
    v = kv_dequantize(take(pool.v_pool),
                      None if pool.v_scale is None else take(pool.v_scale),
                      fmt, out_dtype)
    return attention.KVCache(k=k, v=v, pos=take(pool.page_pos))


def _scatter(pool: PagedKVCache, flat_idx: jax.Array, k_new, v_new,
             pos_tag: jax.Array, fmt: KVFormat) -> PagedKVCache:
    """Write token vectors at flat pool slots (shared scatter core).

    flat_idx/pos_tag: (n,); k_new/v_new: (n, Hkv, D) in compute dtype.
    """
    nb, ps = pool.num_blocks, pool.page_size
    kq, ks = kv_quantize(k_new, fmt)
    vq, vs = kv_quantize(v_new, fmt)
    kq = kq.astype(pool.k_pool.dtype)
    vq = vq.astype(pool.v_pool.dtype)
    out = PagedKVCache(
        k_pool=_unflat(_flat(pool.k_pool).at[flat_idx].set(kq), nb, ps),
        v_pool=_unflat(_flat(pool.v_pool).at[flat_idx].set(vq), nb, ps),
        page_pos=_unflat(_flat(pool.page_pos).at[flat_idx].set(pos_tag),
                         nb, ps),
        k_scale=pool.k_scale if ks is None else _unflat(
            _flat(pool.k_scale).at[flat_idx].set(ks), nb, ps),
        v_scale=pool.v_scale if vs is None else _unflat(
            _flat(pool.v_scale).at[flat_idx].set(vs), nb, ps),
    )
    return out


def _write_target(tables: jax.Array, offset: jax.Array, page_size: int,
                  fallback: jax.Array):
    """Flat pool index for logical ``offset`` per row; rows whose table
    entry is unassigned (-1) redirect into the null block at ``fallback``
    (with the caller writing a -1 tag there, keeping it empty)."""
    page = offset // page_size
    bid = jnp.take_along_axis(tables, page[:, None], axis=1)[:, 0]
    ok = bid >= 0
    flat = jnp.where(ok, bid * page_size + offset % page_size,
                     fallback % page_size)
    return flat, ok


def paged_insert(pool: PagedKVCache, tables: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, pos: jax.Array, *, cache_len: int,
                 fmt: KVFormat) -> PagedKVCache:
    """Decode-step insert: one token per slot at logical ``pos % cache_len``.

    k_new/v_new: (B, Hkv, D); pos: (B,). Slots with no block mapped for the
    target page (inactive slots) write a ``-1`` tag into the null block —
    a no-op for every reader.
    """
    B = k_new.shape[0]
    offset = (pos % cache_len).astype(jnp.int32)
    flat, ok = _write_target(tables, offset, pool.page_size,
                             jnp.arange(B, dtype=jnp.int32))
    tag = jnp.where(ok, pos.astype(jnp.int32), -1)
    return _scatter(pool, flat, k_new, v_new, tag, fmt)


def scatter_chunk(pool: PagedKVCache, table: jax.Array, k_chunk: jax.Array,
                  v_chunk: jax.Array, positions: jax.Array, *,
                  cache_len: int, fmt: KVFormat) -> PagedKVCache:
    """Chunked-prefill scatter: C tokens of one slot into its pages.

    k_chunk/v_chunk: (C, Hkv, D); positions: (C,) absolute, -1 = padding
    (padded tail of the last chunk). table: (T,). Requires C <= cache_len
    so logical offsets within one chunk are distinct.
    """
    C = positions.shape[0]
    safe = jnp.maximum(positions, 0)
    offset = (safe % cache_len).astype(jnp.int32)
    page = offset // pool.page_size
    bid = jnp.take(table, page)
    ok = (positions >= 0) & (bid >= 0)
    flat = jnp.where(ok, bid * pool.page_size + offset % pool.page_size,
                     jnp.arange(C, dtype=jnp.int32) % pool.page_size)
    tag = jnp.where(ok, positions.astype(jnp.int32), -1)
    return _scatter(pool, flat, k_chunk, v_chunk, tag, fmt)


def scatter_chunks(pool: PagedKVCache, tables: jax.Array,
                   k_chunk: jax.Array, v_chunk: jax.Array,
                   positions: jax.Array, *, cache_len: int,
                   fmt: KVFormat) -> PagedKVCache:
    """Batched :func:`scatter_chunk`: C tokens for each of B slots at once
    (the speculative-verify write path — every active slot lands its draft
    window in one scatter).

    k_chunk/v_chunk: (B, C, Hkv, D); positions: (B, C) absolute, -1 =
    padding (shorter-than-C proposals, inactive rows). tables: (B, T).
    Rows with ``-1`` positions or unmapped pages spread into distinct null
    block offsets with ``-1`` tags — never a valid entry, and (because
    each slot's writable pages are exclusively owned after the engine's
    CoW pass) never a cross-slot collision on a real page.
    """
    B, C = positions.shape
    safe = jnp.maximum(positions, 0)
    offset = (safe % cache_len).astype(jnp.int32)            # (B, C)
    page = offset // pool.page_size
    bid = jnp.take_along_axis(tables, page, axis=1)          # (B, C)
    ok = (positions >= 0) & (bid >= 0)
    flat = jnp.where(
        ok, bid * pool.page_size + offset % pool.page_size,
        jnp.arange(B * C, dtype=jnp.int32).reshape(B, C) % pool.page_size)
    tag = jnp.where(ok, positions.astype(jnp.int32), -1)
    Hkv, D = k_chunk.shape[-2:]
    return _scatter(pool, flat.reshape(-1),
                    k_chunk.reshape(B * C, Hkv, D),
                    v_chunk.reshape(B * C, Hkv, D),
                    tag.reshape(-1), fmt)


def scatter_ring(pool: PagedKVCache, table: np.ndarray,
                 ring: attention.KVCache, *, fmt: KVFormat) -> PagedKVCache:
    """Write a prefilled ring cache (one slot, B=1) into pool pages.

    The ring's slot index IS the logical offset (ring size == the slot's
    logical window), so ring slot ``j`` lands at page ``j // ps`` offset
    ``j % ps`` of ``table``. Used by the whole-prompt prefill fallback
    (recurrent / encoder-decoder families) and stacked over L by the
    engine; empty ring entries (pos -1) keep a -1 tag.
    """
    ps = pool.page_size
    W = ring.pos.shape[-1]
    bid = jnp.asarray(np.asarray(table, np.int32)[
        np.arange(W) // ps])                               # (W,)
    ok = bid >= 0
    within = jnp.arange(W, dtype=jnp.int32) % ps
    flat = jnp.where(ok, bid * ps + within, within)        # -1 → null block

    if ring.pos.ndim == 3:                                 # stacked (L, 1, W)
        kseq, vseq, ptag = ring.k[:, 0], ring.v[:, 0], ring.pos[:, 0]
        tag = jnp.where(ok[None], ptag.astype(jnp.int32), -1)

        def one_layer(pool_l, k_l, v_l, tag_l):
            return _scatter(pool_l, flat, k_l, v_l, tag_l, fmt)

        return jax.vmap(one_layer)(pool, kseq, vseq, tag)
    tag = jnp.where(ok, ring.pos[0].astype(jnp.int32), -1)
    return _scatter(pool, flat, ring.k[0], ring.v[0], tag, fmt)


def paged_decode_attention(q: jax.Array, pool: PagedKVCache,
                           tables: jax.Array, pos: jax.Array, *,
                           window: int = 0, fmt: KVFormat, out_dtype,
                           attn_path: str = "gather",
                           kv_partitions=None, live_pages=None,
                           interpret=None) -> jax.Array:
    """Decode attention over the paged pool, on the planned path.

    ``"gather"`` reassembles the slot windows to HBM and runs the
    unchanged ring-cache attention (same masking, same dots) — two passes
    over the KV working set; ``live_pages`` (static) clamps that gather
    to the batch's live-page high-water mark (see ``gather_window``).
    ``"fused"`` walks the block table inside the Pallas kernel
    (``kernels/paged_attention.py``): pages stream through VMEM,
    `kv8_channel` dequant and online softmax fuse into one pass, and the
    clamp is moot — unwritten pages cost one masked VMEM tile, not an
    HBM materialization. Both are token-identical;
    ``planning.plan_attention`` picks per backend (gather on CPU, fused
    on TPU for long contexts).
    """
    if attn_path == "fused":
        from repro.kernels.paged_attention import fused_paged_attention

        return fused_paged_attention(
            q, pool, tables, pos, window=window, fmt=fmt,
            out_dtype=out_dtype, kv_partitions=kv_partitions,
            interpret=interpret)
    if attn_path != "gather":
        raise ValueError(
            f"unknown attn_path {attn_path!r} for paged decode (expected "
            f"gather | fused; 'ring' is the non-paged engine's path)")
    cache = gather_window(pool, tables, fmt=fmt, out_dtype=out_dtype,
                          live_pages=live_pages)
    return attention.decode_attention(q, cache, pos, window=window)


def copy_blocks(pool: PagedKVCache, src: int, dst: int) -> PagedKVCache:
    """Copy-on-write: duplicate physical block ``src`` into ``dst``.

    Works on a per-layer pool or the layer-stacked one — the block axis is
    always ``page_pos.ndim - 2`` for every leaf family.
    """
    axis = pool.page_pos.ndim - 2

    def cp_leaf(leaf):
        idx_src = (slice(None),) * axis + (src,)
        idx_dst = (slice(None),) * axis + (dst,)
        return leaf.at[idx_dst].set(leaf[idx_src])

    return PagedKVCache(
        k_pool=cp_leaf(pool.k_pool),
        v_pool=cp_leaf(pool.v_pool),
        page_pos=cp_leaf(pool.page_pos),
        k_scale=None if pool.k_scale is None else cp_leaf(pool.k_scale),
        v_scale=None if pool.v_scale is None else cp_leaf(pool.v_scale),
    )


def reset_blocks(pool: PagedKVCache, blocks: Sequence[int]) -> PagedKVCache:
    """Wipe the pos tags of freed blocks (eviction hygiene, the paged
    counterpart of ``attention.cache_reset_slots``): stale K/V bytes stay
    but become unreachable, and a block re-entering the free pool can never
    leak a previous occupant's entries to its next owner."""
    idx = jnp.asarray(np.asarray(blocks, np.int32))
    axis = pool.page_pos.ndim - 2
    sl = (slice(None),) * axis + (idx,)
    return pool._replace(page_pos=pool.page_pos.at[sl].set(-1))


# ---------------------------------------------------------------------------
# host side: ref-counted block allocator + prefix-sharing index
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Ref-counted physical-block allocator with a prefix-sharing index and
    cross-request warm-prefix retention.

    Pure host-side bookkeeping: the engine's admit/evict scheduler drives
    alloc/free, and the chain-hash ``lookup``/``publish`` index maps
    page-aligned prompt-prefix content to physical blocks so identical
    prefixes across slots share pages (ref > 1) until the first divergent
    write copy-on-writes them apart (:meth:`cow`).

    With a nonzero ``warm_bytes`` budget, a *published* block whose
    refcount drops to 0 is not freed — it parks in a warm LRU (its index
    entry stays live), so a returning prompt re-adopts its prefix chain
    with zero prefill work. Warm blocks are reclaimed coldest-first when
    the budget overflows or the free list runs dry; reclaimed block ids
    accumulate in :meth:`take_reclaimed` so the engine can wipe their
    stale pos tags before reuse (warm blocks skip the decref-time wipe —
    their content IS the cache).
    """

    def __init__(self, num_blocks: int, page_size: int, *,
                 warm_bytes: int = 0, block_bytes: int = 1):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (block 0 is the null "
                             "block)")
        self.num_blocks = int(num_blocks)
        self.page_size = int(page_size)
        self.warm_bytes = int(warm_bytes)
        self.block_bytes = max(1, int(block_bytes))
        self._free = collections.deque(range(1, num_blocks))
        self._ref: dict = {}          # bid -> refcount (live blocks only)
        self._index: dict = {}        # prefix key -> bid
        self._key_of: dict = {}       # bid -> prefix key
        self._meta: dict = {}         # prefix key -> cached payload
        self._warm = collections.OrderedDict()   # bid -> key, LRU order
        self._reclaimed: List[int] = []          # warm blocks freed, tags
                                                 # not yet wiped on device

    # -- capacity ---------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return len(self._ref)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def warm_pages(self) -> int:
        return len(self._warm)

    @property
    def warm_bytes_used(self) -> int:
        return len(self._warm) * self.block_bytes

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def is_warm(self, bid: int) -> bool:
        return bid in self._warm

    # -- alloc / free -----------------------------------------------------
    def _drop_key(self, bid: int) -> None:
        key = self._key_of.pop(bid, None)
        if key is not None:
            self._index.pop(key, None)
            self._meta.pop(key, None)

    def _reclaim_warm(self) -> Optional[int]:
        """Free the coldest warm block; returns its id (or None)."""
        if not self._warm:
            return None
        bid, _key = self._warm.popitem(last=False)
        self._drop_key(bid)
        self._free.append(bid)
        self._reclaimed.append(bid)
        return bid

    def take_reclaimed(self) -> List[int]:
        """Warm blocks freed since the last call — the engine must wipe
        their pos tags (``reset_blocks``) before they are written again."""
        out, self._reclaimed = self._reclaimed, []
        return out

    def purge_warm(self) -> List[int]:
        """Drop every warm block back to the free list (run boundaries,
        property tests). Returns the purged block ids."""
        purged = []
        while self._warm:
            purged.append(self._reclaim_warm())
        return purged

    def alloc(self) -> int:
        if not self._free:
            self._reclaim_warm()
        if not self._free:
            raise RuntimeError(
                f"KV block pool exhausted ({self.num_blocks - 1} usable "
                f"blocks of {self.page_size} tokens, all referenced); size "
                f"the pool with configs.shapes.serve_num_pages or admit "
                f"fewer concurrent requests")
        bid = self._free.popleft()
        self._ref[bid] = 1
        return bid

    def incref(self, bid: int) -> None:
        self._ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; returns True when the block was freed (the
        caller must then wipe its tags via :func:`reset_blocks`). A
        published block under a nonzero warm budget is *retained* instead
        (returns False — its content stays adoptable); the coldest warm
        blocks are reclaimed if the byte budget would overflow."""
        self._ref[bid] -= 1
        if self._ref[bid]:
            return False
        del self._ref[bid]
        key = self._key_of.get(bid)
        if key is not None and self.warm_bytes >= self.block_bytes:
            while self.warm_bytes_used + self.block_bytes > self.warm_bytes:
                self._reclaim_warm()
            self._warm[bid] = key
            self._warm.move_to_end(bid)
            return False
        self._drop_key(bid)
        self._free.append(bid)
        return True

    def cow(self, bid: int) -> int:
        """Copy-on-write bookkeeping for a shared block the caller is about
        to write: allocate a private replacement (the caller device-copies
        the payload via :func:`copy_blocks`) and release the shared ref.
        The published prefix key stays with the *old* block, whose content
        still matches it."""
        if self.refcount(bid) < 2:
            raise ValueError(f"block {bid} is not shared (ref "
                             f"{self.refcount(bid)}); nothing to CoW")
        new = self.alloc()
        self.decref(bid)
        return new

    # -- prefix sharing ---------------------------------------------------
    def peek(self, key: str) -> Optional[int]:
        """Like :meth:`lookup` but without taking a reference (admit-gate
        capacity previews)."""
        return self._index.get(key)

    def lookup(self, key: str) -> Optional[int]:
        """Find a published block for ``key`` and take a reference on it.
        A warm (refcount-0, retained) block is adopted back to live."""
        bid = self._index.get(key)
        if bid is None:
            return None
        if bid in self._warm:
            del self._warm[bid]
            self._ref[bid] = 1
        else:
            self.incref(bid)
        return bid

    # -- first-token metadata --------------------------------------------
    def set_meta(self, key: str, value) -> None:
        """Attach a payload (the engine caches the first decoded token) to
        a *published* chain key; dropped whenever the key is."""
        if key in self._index:
            self._meta[key] = value

    def meta(self, key: str):
        return self._meta.get(key)

    def publish(self, key: str, bid: int) -> None:
        """Register ``bid``'s content under ``key`` (first writer wins; a
        block carries at most one key)."""
        if key in self._index or bid in self._key_of:
            return
        self._index[key] = bid
        self._key_of[bid] = key

    def unpublish(self, bid: int) -> None:
        """Drop ``bid``'s index entry because its content is about to be
        overwritten in place (a refcount-1 owner writing without CoW —
        e.g. a wrapped SWA decode recycling its own prompt pages). A
        published key must always describe the block's current bytes, or
        a later identical prompt would adopt destroyed content."""
        key = self._key_of.pop(bid, None)
        if key is not None:
            self._index.pop(key, None)
            self._meta.pop(key, None)


# ---------------------------------------------------------------------------
# prefix keys: chain hash over page-aligned prompt content
# ---------------------------------------------------------------------------

def position_units(tokens, prefix_embeds=None) -> List[bytes]:
    """One canonical byte string per prefill position.

    The prefill stream is ``[vision-prefix embeds] + prompt tokens`` —
    embeds hash by value so two requests share pages only when *both* the
    patches and the token prefix agree.
    """
    units: List[bytes] = []
    if prefix_embeds is not None:
        arr = np.asarray(jax.device_get(prefix_embeds))
        for row in arr.reshape(arr.shape[0], -1):
            units.append(b"E" + row.tobytes())
    for t in np.asarray(jax.device_get(tokens), np.int64).reshape(-1):
        units.append(b"T" + int(t).to_bytes(8, "little", signed=True))
    return units


def page_keys(units: Sequence[bytes], page_size: int, *,
              seed: bytes = b""
              ) -> Tuple[List[str], Optional[Tuple[str, int]]]:
    """Chain-hash keys for the page-aligned prefix of a prefill stream.

    Returns ``(full_page_keys, partial)``: one key per *full* page (key i
    commits to every position <= page i's end, so matching keys imply
    matching whole prefixes), plus ``(key, fill)`` for a trailing partial
    page when the stream doesn't end on a page boundary.

    ``seed`` folds request-level context that shapes *every* cached
    position into the chain — e.g. encoder-decoder audio frames, which
    feed each decoder layer's input through cross-attention, so two
    identical token prompts over different audio must never share pages.
    """
    h = hashlib.sha256()
    if seed:
        h.update(seed)
    full: List[str] = []
    partial = None
    n = len(units)
    for i, u in enumerate(units):
        h.update(len(u).to_bytes(4, "little"))
        h.update(u)
        if (i + 1) % page_size == 0:
            full.append(h.hexdigest())
    fill = n % page_size
    if fill:
        partial = (h.hexdigest() + f"+{fill}", fill)
    return full, partial
