"""Serving metrics plane: counters, gauges, histograms + percentiles.

One small registry shared by the serving stack: the engine samples it once
per :meth:`ServingEngine.step` (queue depth, active slots, pages in use,
TTFT, per-step decode time), the front door (``runtime/frontdoor.py``)
adds admission-side series (queue wait, 429/408 rejections, cancels), and
``GET /metrics`` renders the whole registry in Prometheus text exposition
format. The same nearest-rank percentile helpers back
:meth:`ServeReport.latency_stats`, so the CLI report, the final
``ServeReport`` and the ``/metrics`` endpoint can never disagree on what
"p99" means.

No external dependency — stdlib only, like the rest of the runtime.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Sequence

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "nearest_rank", "summarize",
]

QUANTILES = (0.5, 0.95, 0.99)


def nearest_rank(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile: the smallest element with at least
    ``ceil(q * n)`` elements ≤ it. Exact (no interpolation), so two code
    paths computing "p99" over the same samples agree bit-for-bit."""
    if not 0.0 < q <= 1.0:
        raise ValueError(f"quantile must be in (0, 1], got {q}")
    vs = sorted(values)
    if not vs:
        return 0.0
    return float(vs[max(1, math.ceil(q * len(vs))) - 1])


def summarize(values: Sequence[float]) -> Dict[str, float]:
    """p50/p95/p99 + mean/max summary of a latency sample set."""
    vs = list(values)
    out = {f"p{int(q * 100)}": nearest_rank(vs, q) for q in QUANTILES}
    out["max"] = float(max(vs)) if vs else 0.0
    out["mean"] = float(sum(vs) / len(vs)) if vs else 0.0
    out["count"] = float(len(vs))
    return out


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help

    def render(self) -> List[str]:
        raise NotImplementedError

    def _header(self) -> List[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        return lines


class Counter(_Metric):
    """Monotonic event count."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n

    def render(self) -> List[str]:
        return self._header() + [f"{self.name} {self.value}"]


class Gauge(_Metric):
    """Point-in-time value (queue depth, pages in use); tracks its peak."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self.value = 0.0
        self.peak = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)
        self.peak = max(self.peak, self.value)

    def render(self) -> List[str]:
        return self._header() + [f"{self.name} {_fmt(self.value)}"]


class Histogram(_Metric):
    """Sample store with exact nearest-rank quantiles.

    Serving runs here are bounded (one report per run), so every sample is
    kept and quantiles are exact — rendered as a Prometheus *summary*
    (which is what client-side exact quantiles are), not a bucketed
    histogram approximation.
    """

    kind = "summary"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self.values: List[float] = []
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.values.append(float(v))
        self.sum += float(v)

    @property
    def count(self) -> int:
        return len(self.values)

    def percentile(self, q: float) -> float:
        return nearest_rank(self.values, q)

    def summary(self) -> Dict[str, float]:
        return summarize(self.values)

    def render(self) -> List[str]:
        lines = self._header()
        for q in QUANTILES:
            lines.append(
                f'{self.name}{{quantile="{q}"}} {_fmt(self.percentile(q))}')
        lines.append(f"{self.name}_sum {_fmt(self.sum)}")
        lines.append(f"{self.name}_count {self.count}")
        return lines


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


class MetricsRegistry:
    """Get-or-create registry of named metrics, rendered as one page.

    The registry is touched from the asyncio event loop (front door) and
    from the engine-step executor thread; every mutation is a single
    attribute update on a metric object, but get-or-create itself is
    locked so two threads can't race a metric into existence twice.
    """

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"not {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> Iterable[str]:
        return self._metrics.keys()

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict view (reports, tests, JSON artifacts)."""
        out: Dict[str, object] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                out[name] = m.summary()
            elif isinstance(m, Gauge):
                out[name] = {"value": m.value, "peak": m.peak}
            else:
                out[name] = m.value
        return out

    def render(self) -> str:
        """Prometheus text exposition format (the ``GET /metrics`` body)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + "\n"
