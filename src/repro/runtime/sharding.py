"""Name-based sharding rules (Megatron TP + optional ZeRO-3/FSDP).

Rules are applied leaf-wise over the param pytree; a dim is sharded over a
mesh axis only when divisible, so every architecture — from whisper-small to
llama3-405b — lowers on the same fixed production mesh (small archs simply
replicate where they don't divide; see DESIGN.md).

W4A16 leaves: a QuantizedTensor's packed (K/2, N) payload and its (K/g, N)
scales shard with the *same* logical rule as the dense (K, N) weight, so
each TP rank dequantizes only its own shard — the paper's kernel made
TP-composable with zero cross-device dequant traffic.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.quant import QuantizedTensor

# column-parallel: output features sharded over "model"
COL = {"wq", "wk", "wv", "w_gate", "w_up", "tm_r", "tm_k", "tm_v", "tm_g",
       "tm_w", "cm_k", "in_proj", "dt_proj", "lm_head"}
# row-parallel: input features (K) sharded over "model"
ROW = {"wo", "w_down", "tm_o", "cm_v", "out_proj"}
# always replicated (small / routing-sensitive)
REP = {"router", "bc_proj"}


def _names(path):
    return [str(getattr(k, "key", getattr(k, "name", k))) for k in path]


def _divisible(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def _axis_size(mesh, name: str) -> int:
    try:
        return mesh.shape[name]
    except (KeyError, TypeError):
        return 0


def _matrix_spec(shape, mesh, kind: str, fsdp: bool, fsdp_axis: str):
    """Spec for a (..., K, N) weight; leading dims are stacking (L/E)."""
    nd = len(shape)
    spec = [None] * nd
    model = _axis_size(mesh, "model")
    fs = _axis_size(mesh, fsdp_axis) if fsdp else 0
    if kind == "col":
        if _divisible(shape[-1], model):
            spec[-1] = "model"
        if fsdp and _divisible(shape[-2], fs):
            spec[-2] = fsdp_axis
    elif kind == "row":
        if _divisible(shape[-2], model):
            spec[-2] = "model"
        if fsdp and _divisible(shape[-1], fs):
            spec[-1] = fsdp_axis
    else:  # replicated matrix, optionally fsdp on K
        if fsdp and _divisible(shape[-2], fs):
            spec[-2] = fsdp_axis
    return P(*spec)


def _leaf_kind(names) -> str:
    for n in reversed(names):
        if n in REP:
            return "rep"
        if n in COL:
            return "col"
        if n in ROW:
            return "row"
    return "rep"


def leaf_kind_for_path(path) -> str:
    """TP kind ("col" | "row" | "rep") of a param-tree leaf by its key path.

    Public entry for shard-local planning (kernels/planning.py): the same
    name rules that decide how a weight is sharded decide which of its GEMM
    dims (N for col, K for row) shrinks per rank."""
    return _leaf_kind(_names(path))


def param_shardings(params, mesh, *, fsdp: bool = False,
                    fsdp_axis: str = "data"):
    """Pytree of NamedSharding matching ``params`` (QuantizedTensor-aware)."""
    model = _axis_size(mesh, "model")

    def spec_for(names, leaf) -> P:
        if "embed" in names:                       # (V, d): vocab-sharded
            s = [None] * leaf.ndim
            if _divisible(leaf.shape[-2], model):
                s[-2] = "model"
            if fsdp and _divisible(leaf.shape[-1], _axis_size(mesh, fsdp_axis)):
                s[-1] = fsdp_axis
            return P(*s)
        kind = _leaf_kind(names)
        if leaf.ndim >= 2 and "kernel" in names:
            return _matrix_spec(leaf.shape, mesh, kind, fsdp, fsdp_axis)
        return P()                                  # norms, biases, scalars

    def visit(path, leaf):
        names = _names(path)
        if isinstance(leaf, QuantizedTensor):
            pk = spec_for(names, leaf.packed)
            # scales/zeros follow the same rule applied to their own shapes
            sc = _matrix_spec(leaf.scales.shape, mesh, _leaf_kind(names),
                              fsdp, fsdp_axis) if "kernel" in names else P()
            mk = lambda s: NamedSharding(mesh, s)
            return QuantizedTensor(
                packed=mk(pk), scales=mk(sc),
                zeros=None if leaf.zeros is None else mk(sc),
                group_size=leaf.group_size, out_dtype=leaf.out_dtype,
                format=leaf.format)
        return NamedSharding(mesh, spec_for(names, leaf))

    return jax.tree_util.tree_map_with_path(
        visit, params,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))


def _axis_entry(spec_axes):
    """First entry of a batch_spec as a PartitionSpec element, normalizing a
    singleton tuple to the bare axis name (older jax compares them unequal)."""
    if len(spec_axes) == 0 or spec_axes[0] is None:
        return None
    a = spec_axes[0]
    return a[0] if isinstance(a, tuple) and len(a) == 1 else a


def batch_spec(B: int, mesh) -> P:
    """Shard the batch dim over as many DP axes as divisibility allows."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    prod = 1
    for a in axes:
        if B % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    return P(tuple(chosen) if chosen else None)


def batch_axis_entry(B: int, mesh):
    """The normalized PartitionSpec entry for a batch dim of size ``B``.

    The single source for batch-axis entries in BOTH input and output
    shardings: every caller (data_shardings, the jit step out_shardings)
    goes through the same singleton-tuple normalization, so prefill/serve
    out_shardings can never disagree with the input shardings on older jax
    (where ``P(("data",))`` and ``P("data")`` compare unequal).
    """
    return _axis_entry(batch_spec(B, mesh))


def data_shardings(tree, mesh, *, batch_axis: int = 0):
    """Shard every array leaf's batch dim per batch_spec; rest replicated.

    Leaves with no batch dim (0-d scalars, or fewer dims than
    ``batch_axis`` addresses) are replicated instead of indexing past the
    end of their spec."""

    def visit(leaf):
        if leaf.ndim <= batch_axis:            # scalar / missing batch dim
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        spec[batch_axis] = batch_axis_entry(leaf.shape[batch_axis], mesh)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(visit, tree)


def decode_state_shardings(state, cfg, mesh):
    """KV caches: batch over DP axes; cache length over "model" when the
    batch can't use it — sequence-parallel decode attention (beyond-paper
    distribution; see DESIGN.md).

    Paged-pool leaves (``runtime/kvcache.PagedKVCache``) have no batch dim:
    pages replicate over the DP axes (every rank sees the whole pool — the
    block tables are what shard with the batch) and the KV-head dim shards
    over "model", matching the per-step k/v "bhd" activation sharding so
    scatter/gather stay rank-local along heads.
    """
    model = _axis_size(mesh, "model")

    def visit(path, leaf):
        names = _names(path)
        spec = [None] * leaf.ndim
        if any(n in ("k_pool", "v_pool", "k_scale", "v_scale", "page_pos")
               for n in names):
            # (L, nb, ps, Hkv, D) / (L, nb, ps, Hkv) / (L, nb, ps):
            # replicate pages over DP; shard the head dim over model
            if leaf.ndim >= 4 and _divisible(leaf.shape[3], model):
                spec[3] = "model"
            return NamedSharding(mesh, P(*spec))
        # layer-stacked leaves: axis0=L, axis1=B, then shape-specific
        if leaf.ndim >= 2:
            spec[1] = _axis_entry(batch_spec(leaf.shape[1], mesh))
        if ("k" in names or "v" in names or "pos" in names) and leaf.ndim >= 3:
            # KVCache leaves (L, B, W, [Hkv, D]) — shard window over model
            if _divisible(leaf.shape[2], model):
                spec[2] = "model"
        elif "wkv" in names and leaf.ndim == 5:
            # rwkv state (L, B, H, hd, hd): shard heads over model
            if _divisible(leaf.shape[2], model):
                spec[2] = "model"
        elif "ssm" in names and leaf.ndim == 4:
            # (L, B, d_inner, n): shard d_inner over model
            if _divisible(leaf.shape[2], model):
                spec[2] = "model"
        elif "enc_kv" in names and leaf.ndim == 5:
            if _divisible(leaf.shape[2], model):
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(visit, state)
