"""Mesh-sharded serving engine: continuous batched decode over request slots.

The paper's deployment regime — decode GEMMs with small M and K ≫ N — only
materializes when a *serving loop* drives the kernels: a fixed pool of batch
slots, requests admitted and evicted per step, one jitted decode step over
the whole pool. This module provides that loop:

  :class:`Request`       — one generation request (prompt, budget, arrival).
  :class:`ServingEngine` — slot scheduler + compiled prefill/decode steps.
  :class:`ServeReport`   — per-request tokens/latency + per-step throughput.

Context is stored in a **paged, prefix-shared KV cache** by default
(``runtime/kvcache.py``): one physical block pool per layer, per-slot block
tables, and a ref-counted host-side allocator driven by the admit/evict
scheduler. Identical prompt prefixes across slots map to the same physical
blocks (chain-hash index) until the first divergent write copies them apart
— so B slots serving the same prompt hold ~1 slot's worth of pages. A
slot's logical window keeps the exact ring layout (token at ``pos %
cache_len``), which makes paged decode token-identical to the legacy ring
engine (``paged=False``), SWA/vision-prefix masking included.

Slot lifecycle (see docs/serving.md):

  admit   — a free slot takes the next arrived request; its pages are
            shared-or-allocated and its prompt prefills in **chunks of
            ``prefill_chunk`` tokens interleaved with decode steps** —
            the single prefill path for every family (recurrent carries
            and enc-dec cross-KV thread through the chunk step) — a long
            prompt never stalls decode for the already-running slots. A
            page-aligned prefix retained warm in the allocator (see
            ``warm_cache_mb``) re-admits with zero prefill steps.
  decode  — one ``serve_step`` over all ``max_batch`` slots; inactive
            slots' writes are redirected into the null block and their
            outputs ignored.
  evict   — a finished slot's blocks are dereferenced; blocks reaching
            refcount 0 get their pos tags wiped and return to the free
            pool.

On a mesh the steps are jitted with the shardings of ``runtime/steps.py``
(params TP/FSDP-sharded; pool pages replicated over DP with heads over TP,
block tables batch-sharded), and kernel plans are chosen shard-local.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import serve_cache_len, serve_num_pages
from repro.core import compat
from repro.core.quant import (
    DEFAULT_KV_FORMAT, QuantizedTensor, get_kv_format,
)
from repro.kernels import planning
from repro.models import attention, layers
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime import kvcache as kvc
from repro.runtime import metrics as rmetrics
from repro.runtime import sharding as shd
from repro.runtime import speculative as spec
from repro.runtime import steps as rsteps

__all__ = ["Request", "ServeReport", "ServingEngine", "StepEvents",
           "insert_slot", "reset_slot"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token array; ``max_new_tokens`` counts every
    generated token including the one produced by prefill. ``arrival_step``
    simulates request arrival: the scheduler won't admit the request before
    that decode step. Prefix/audio embeddings are per-request frontends
    ((vision_prefix, d) / (encoder_seq, d)); when the arch needs them and
    the request doesn't carry them, the engine substitutes zeros.

    ``deadline_s`` (client SLO, seconds from submission) and ``priority``
    (higher admits first) only shape *admission ordering*, and only under
    ``admission="priority"`` (the front door's mode) — the default FIFO
    scheduler, and therefore every existing :meth:`ServingEngine.run`
    caller, ignores both. Deadline *enforcement* (408 drops) lives in the
    front door's queue, before the engine ever sees the request.
    """

    rid: int
    prompt: Any
    max_new_tokens: int
    arrival_step: int = 0
    prefix_embeds: Any = None
    audio_embeds: Any = None
    deadline_s: Optional[float] = None
    priority: int = 0


@dataclasses.dataclass
class ServeReport:
    """What a :meth:`ServingEngine.run` (or a front-door session) produced."""

    results: Dict[int, List[int]]          # rid → generated token ids
    latencies: Dict[int, float]            # rid → admit→finish seconds
    steps: int = 0
    decode_tokens: int = 0                 # tokens EMITTED (accepted), not
                                           # positions scored — speculative
                                           # and baseline runs compare 1:1
    decode_s: float = 0.0
    prefill_s: float = 0.0
    warm_hits: int = 0                     # admits that adopted ≥1 warm page
    warm_misses: int = 0                   # admits that found none warm
    prefill_steps_saved: int = 0           # chunk steps avoided by shared /
                                           # warm prefix pages, summed
    step_records: List[dict] = dataclasses.field(default_factory=list)
    peak_pages: int = 0                    # paged: max live blocks seen
    proposed_tokens: int = 0               # speculative: drafts scored
    accepted_tokens: int = 0               # speculative: drafts accepted
    ttft: Dict[int, float] = dataclasses.field(default_factory=dict)
    # rid → admit→first-token seconds
    cancelled: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    # rid → tokens emitted before cancellation (waiting-queue cancels: [])
    admitted: int = 0                      # requests that reached a slot
    # front-door admission outcomes (the engine never counts these itself;
    # a 429/408 by definition never touched the engine)
    rejected_429: int = 0                  # queue-full rejections
    rejected_408: int = 0                  # expired-deadline drops
    peak_queue_depth: int = 0              # front-door queue high-water mark
    queue_wait: Dict[int, float] = dataclasses.field(default_factory=dict)
    # rid → seconds in the front-door queue before engine submission

    @property
    def tokens_per_s(self) -> float:
        """*Accepted* tokens per decode second (every counted token is a
        committed output token; rejected drafts cost time, not tokens)."""
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def acceptance_rate(self) -> float:
        return (self.accepted_tokens / self.proposed_tokens
                if self.proposed_tokens else 0.0)

    def latency_stats(self) -> Dict[str, float]:
        """Nearest-rank p50/p95/p99 (+ mean/max) over per-request
        admit→finish latency — the one percentile code path shared by the
        serve CLI, the front door and ``GET /metrics``."""
        return rmetrics.summarize(list(self.latencies.values()))

    def ttft_stats(self) -> Dict[str, float]:
        """Same summary over per-request time-to-first-token."""
        return rmetrics.summarize(list(self.ttft.values()))


@dataclasses.dataclass
class StepEvents:
    """What one :meth:`ServingEngine.step` did — the streaming contract.

    The front door turns ``emitted`` into SSE chunks (tokens flush to the
    client per engine step, not per run) and ``finished`` into stream
    terminations. ``worked`` is False when the engine had nothing resident
    (the step was a no-op and the step counter did not advance).
    """

    step: int
    emitted: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    finished: List[int] = dataclasses.field(default_factory=list)
    admitted: List[int] = dataclasses.field(default_factory=list)
    worked: bool = True


class _Slot:
    """Mutable per-slot scheduler record."""

    __slots__ = ("req", "tokens", "remaining", "pos_next", "t_admit",
                 "phase", "pf_stream", "pf_next", "pf_total", "pf_keys",
                 "prompt_ids")

    def __init__(self, req: Request, pos0: int, t_admit: float):
        self.req = req
        self.prompt_ids: Optional[List[int]] = None   # set when speculating
        self.tokens: List[int] = []
        self.remaining = req.max_new_tokens
        self.pos_next = pos0
        self.t_admit = t_admit
        self.phase = "prefill"          # "prefill" → "active"
        self.pf_stream = None           # (S_total, d) embedding stream
        self.pf_next = 0                # next prefill position
        self.pf_total = 0               # prompt + vision-prefix length
        self.pf_keys = ([], None)       # prefix-share keys to publish

    def emit_first(self, first_token: int) -> None:
        self.tokens.append(first_token)
        self.remaining -= 1
        self.phase = "active"


def insert_slot(state, rstate, slot: int):
    """Write a B=1 prefilled decode state into batch slot ``slot``.

    Every per-slot decode-state leaf is (L, B, ...) — ring KV caches,
    rwkv/ssm states, encoder cross-attention KV — so one rule covers all
    families. The whole slot row is overwritten, ring pos tags included: a
    reused slot can never see a stale entry from its previous occupant.
    (Paged pool leaves are not per-slot; the paged engine scatters into
    them via ``kvcache.scatter_ring`` instead.)
    """
    return jax.tree.map(
        lambda s, r: s.at[:, slot].set(r[:, 0].astype(s.dtype)),
        state, rstate)


def reset_slot(state, slot: int):
    """Evict ``slot`` (ring mode): wipe its KV ring tags so the row reads
    as empty. The paged engine's counterpart is block-level
    (``kvcache.reset_blocks`` on blocks whose refcount hits 0)."""
    def visit(leaf):
        if isinstance(leaf, attention.KVCache):
            return attention.cache_reset_slots(leaf, slot)
        return leaf

    return jax.tree.map(
        visit, state, is_leaf=lambda x: isinstance(x, attention.KVCache))


class ServingEngine:
    """Continuous-batching decode over ``max_batch`` request slots.

    ``paged=True`` (default) stores context in the paged, prefix-shared
    block pool; ``paged=False`` keeps the legacy per-slot ring caches
    (the reference the parity suite compares against). Paged mode always
    prefills in chunks — the one prefill path, every family: at most
    ``prefill_chunk`` (default 32) prompt tokens are processed per engine
    step, interleaved with decode; recurrent carries (rwkv/hybrid) and
    enc-dec cross-KV thread through the chunk step. ``kv_format`` selects
    the KV block storage (``kv_fp16`` passthrough or ``kv8_channel``
    per-head INT8 — paged mode only). ``warm_cache_mb`` budgets the
    allocator's warm prefix retention: fully-released page-aligned prefix
    chains stay resident (LRU by chain) up to that many MiB, and a
    returning prefix re-admits without recomputing its prefill.

    ``mesh=None`` runs single-device (plain ``jax.jit``); with a mesh the
    steps are jitted with explicit shardings and the kernel plans are
    chosen shard-local (see module docstring).
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 max_batch: int = 8, max_prompt_len: int = 128,
                 max_new_tokens: int = 64, refine_plans: bool = False,
                 cache_len: Optional[int] = None, paged: bool = True,
                 page_size: int = 16, prefill_chunk: Optional[int] = None,
                 kv_format: Optional[str] = None,
                 num_pages: Optional[int] = None,
                 warm_cache_mb: float = 0.0,
                 speculate=None, spec_k: int = 4,
                 admission: str = "fifo",
                 attn_path: str = "auto"):
        self.mesh = mesh
        if admission not in ("fifo", "priority"):
            raise ValueError(f"admission must be 'fifo' or 'priority', "
                             f"got {admission!r}")
        self.admission = admission
        self.max_batch = int(max_batch)
        self.max_prompt_len = int(max_prompt_len)
        self.max_new_tokens = int(max_new_tokens)
        # rwkv holds no KV cache at all — "paged" degenerates to the ring
        # state (nothing to page); everything else pages by default
        self.paged = bool(paged) and cfg.family != "rwkv"
        self.page_size = int(page_size)
        self.kv_format = kv_format or DEFAULT_KV_FORMAT
        self._kvfmt = get_kv_format(self.kv_format)
        if self._kvfmt.quantized and not self.paged:
            if cfg.attn_free:
                raise ValueError(
                    f"kv_format {self.kv_format!r} does not apply to "
                    f"{cfg.family!r} archs — they hold no KV cache to "
                    f"quantize; use kv_fp16")
            raise ValueError(
                f"kv_format {self.kv_format!r} quantizes KV blocks, which "
                f"needs the paged cache (paged=True)")
        ps = self.page_size if self.paged else None
        if cache_len is None:
            self.cache_len = serve_cache_len(cfg, max_prompt_len,
                                             max_new_tokens, ps)
        else:
            self.cache_len = int(cache_len)
            if ps:
                self.cache_len = -(-self.cache_len // ps) * ps
        # chunked prefill is the single prefill path whenever the caller
        # asked for the paged engine — including rwkv, whose "paged" mode
        # degenerates to ring state but still streams its prompt in chunks
        self.chunked = bool(paged)
        # prefix pages can only be *skipped* when no recurrent carry must
        # consume every prompt token — carry families recompute each token
        self.share_prefix = self.paged and cfg.family not in T.CARRY_FAMILIES
        if self.paged:
            self.pages_slot = self.cache_len // self.page_size
            self.num_pages = int(
                num_pages if num_pages is not None
                else serve_num_pages(cfg, max_prompt_len, max_new_tokens,
                                     page_size=self.page_size,
                                     max_batch=self.max_batch))
            if self.num_pages < self.pages_slot + 1:
                raise ValueError(
                    f"num_pages={self.num_pages} cannot hold even one "
                    f"slot's window ({self.pages_slot} pages + the null "
                    f"block) — the admit gate would wait forever; size "
                    f"the pool with configs.shapes.serve_num_pages")
            # bytes one block occupies across every layer's pool leaves
            # (scales + pos tags included) — the warm LRU budget unit
            pool_abs = jax.eval_shape(
                lambda: kvc.init_pool(
                    self.num_pages, self.page_size, cfg.num_kv_heads,
                    cfg.head_dim, cfg.dtype, kv_format=self.kv_format))
            block_bytes = sum(
                l.size * jnp.dtype(l.dtype).itemsize
                for l in jax.tree_util.tree_leaves(pool_abs)
            ) // self.num_pages * cfg.num_layers
            warm_bytes = int(float(warm_cache_mb) * (1 << 20)) \
                if self.share_prefix else 0
            self.alloc = kvc.BlockAllocator(
                self.num_pages, self.page_size,
                warm_bytes=warm_bytes, block_bytes=block_bytes)
        else:
            self.pages_slot = 0
            self.num_pages = 0
            self.alloc = None
        self.prefill_chunk = max(
            1, min(int(prefill_chunk) if prefill_chunk is not None else 32,
                   self.cache_len))

        # decode-attention path: a costed plan decision, same shape as the
        # matmul planner — "auto" ranks ring/gather/fused on the engine's
        # true decode problem (gather on CPU hosts, fused on TPU for long
        # contexts); a forced path is validated against the engine mode
        # (e.g. "fused" without the paged cache is refused loudly)
        attn_problem = planning.AttentionProblem(
            B=self.max_batch, Hq=cfg.num_heads, Hkv=cfg.num_kv_heads,
            D=cfg.head_dim, cache_len=self.cache_len,
            page_size=self.page_size, window=cfg.sliding_window,
            kv_format=self.kv_format, paged=self.paged,
            backend=jax.default_backend(),
            act_bytes=jnp.dtype(cfg.dtype).itemsize)
        forced_path = None if attn_path == "auto" else attn_path
        attn_plan = planning.plan_attention(attn_problem, path=forced_path)
        self.attn_path = attn_plan.path
        self.kv_partitions = attn_plan.kv_partitions
        # chunked prefill is a *different* attention problem than decode —
        # q_len = the prefill chunk, one slot per call — so it gets its
        # own costed plan (the multi-query fused kernel serves q_len > 1;
        # the gather/fused tradeoff is priced per regime, not copied from
        # the decode pick). A forced path forces every regime.
        if self.paged and self.chunked:
            pf_plan = planning.plan_attention(
                dataclasses.replace(attn_problem, B=1,
                                    q_len=self.prefill_chunk),
                path=forced_path)
            self.prefill_attn_path = pf_plan.path
            self.prefill_kv_partitions = pf_plan.kv_partitions
        else:
            self.prefill_attn_path = self.attn_path
            self.prefill_kv_partitions = self.kv_partitions

        self.spec_k = int(spec_k)
        self.proposer: Optional[spec.Proposer] = None
        if speculate is not None and speculate != "off":
            if isinstance(speculate, spec.Proposer):
                spec.validate_speculate(speculate.name, self.spec_k,
                                        cfg=cfg, paged=self.chunked)
                self.proposer = speculate
            else:
                spec.validate_speculate(str(speculate), self.spec_k,
                                        cfg=cfg, paged=self.chunked)
                self.proposer = spec.make_proposer(str(speculate),
                                                   target_cfg=cfg)
        # speculative verify: q_len = k+1 queries per slot, full batch —
        # same plan shape as prefill, at the verify step's true width
        if self.paged and self.proposer is not None:
            vf_plan = planning.plan_attention(
                dataclasses.replace(attn_problem, q_len=self.spec_k + 1),
                path=forced_path)
            self.verify_attn_path = vf_plan.path
            self.verify_kv_partitions = vf_plan.kv_partitions
        else:
            self.verify_attn_path = self.attn_path
            self.verify_kv_partitions = self.kv_partitions

        self.plans: Dict[str, planning.KernelPlan] = {}
        if (getattr(cfg, "w4a16_strategy", "auto") == "auto"
                and getattr(cfg, "w4a16_plan", None) is None
                and any(isinstance(l, QuantizedTensor)
                        for l in jax.tree_util.tree_leaves(
                            params,
                            is_leaf=lambda t: isinstance(t, QuantizedTensor)))):
            # pre-plan the decode-regime GEMMs on the shapes each rank will
            # execute; the per-layer decisions pin the trace-time lookups.
            # Speculative verify widens every decode GEMM to M = B*(k+1)
            # rows — plan at that true local shape, not the M=B decode one
            M = self.max_batch * (self.spec_k + 1) \
                if self.proposer is not None else self.max_batch
            self.plans = planning.plan_for_params(
                params, M=M, mesh=mesh, refine=refine_plans)
            cfg = dataclasses.replace(cfg, w4a16_plan=self.plans)
        self.cfg = cfg

        with self._ctx():
            if mesh is not None:
                pshard = shd.param_shardings(
                    jax.eval_shape(lambda: params), mesh)
                params = jax.device_put(params, pshard)
        self.params = params

        self._prefill_fns: Dict[tuple, Any] = {}
        # decode/chunk/verify steps compile per live-page bucket (None =
        # full table; gather path only — see _live_bucket), so the dicts
        # hold at most 1 + log2(pages_slot) variants each
        self._serve_fns: Dict[Optional[int], Any] = {}
        self._chunk_fns: Dict[Optional[int], Any] = {}
        self._verify_fns: Dict[Optional[int], Any] = {}
        self._embed_fn = None
        self._encode_fn = None
        # interleaved decode steps must not clobber the carries of slots
        # still mid-prefill — those step functions take an "active" mask
        self._needs_active = self.chunked and cfg.family in T.CARRY_FAMILIES
        self._tables = None          # (B, pages_slot) np.int32 block tables
        self._keys_cache: Dict[int, Any] = {}   # id(req) → prefix keys
        self._reserve: Dict[int, int] = {}      # slot → outstanding worst-
                                                # case future allocations
        self.last_state = None       # decode-state snapshot (tests/debug)

        # re-entrant stepper state (armed by start(); run() is a wrapper)
        self.metrics: Optional[rmetrics.MetricsRegistry] = None
        self.report: Optional[ServeReport] = None
        self._started = False
        self._waiting: collections.deque = collections.deque()
        self._slots: List[Optional[_Slot]] = []
        self._events: Optional[StepEvents] = None
        self._state = None
        self._state_dirty = False
        self._serve = None
        self._tok = self._pos = None
        self._step_no = 0

    # -- compiled steps ----------------------------------------------------

    def _ctx(self):
        return compat.set_mesh(self.mesh) if self.mesh is not None \
            else contextlib.nullcontext()

    def _prefill_inputs(self, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        inputs = {"tokens": prompt}
        cfg = self.cfg
        if cfg.vision_prefix:
            inputs["prefix_embeds"] = self._prefix_embeds(req)[None]
        if cfg.family == "encdec":
            ae = req.audio_embeds
            if ae is None:
                ae = jnp.zeros((cfg.encoder_seq, cfg.d_model), cfg.dtype)
            inputs["audio_embeds"] = jnp.asarray(ae, cfg.dtype)[None]
        return inputs

    def _prefix_embeds(self, req: Request):
        pe = req.prefix_embeds
        if pe is None:
            pe = jnp.zeros((self.cfg.vision_prefix, self.cfg.d_model),
                           self.cfg.dtype)
        return jnp.asarray(pe, self.cfg.dtype)

    def _prefill_fn(self, inputs):
        key = tuple(sorted((k, v.shape) for k, v in inputs.items()))
        fn = self._prefill_fns.get(key)
        if fn is None:
            if self.mesh is None:
                fn = jax.jit(rsteps.make_prefill_step(self.cfg,
                                                      self.cache_len))
            else:
                fn = rsteps.jit_prefill_step(
                    self.cfg, self.mesh, self.cache_len,
                    jax.eval_shape(lambda: self.params),
                    jax.eval_shape(lambda: inputs))
            self._prefill_fns[key] = fn
        return fn

    def _init_state(self):
        if self.paged:
            return T.init_paged_state(
                self.cfg, self.max_batch, self.cache_len,
                page_size=self.page_size, num_blocks=self.num_pages,
                kv_format=self.kv_format)
        return T.init_decode_state(self.cfg, self.max_batch, self.cache_len)

    def _serve_inputs_abstract(self):
        inputs = {
            "state": jax.eval_shape(self._init_state),
            "tokens": jax.ShapeDtypeStruct((self.max_batch,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((self.max_batch,), jnp.int32),
        }
        if self.paged:
            inputs["tables"] = jax.ShapeDtypeStruct(
                (self.max_batch, self.pages_slot), jnp.int32)
        if self._needs_active:
            inputs["active"] = jax.ShapeDtypeStruct((self.max_batch,),
                                                    jnp.bool_)
        return inputs

    def _live_bucket(self, hw: int) -> Optional[int]:
        """Live-page bucket for a gather step whose high-water mark is
        ``hw`` pages: halve the full table width while it stays a
        multiple of 2 covering ``hw``, so recompiles are bounded at
        log2(pages_slot) variants while a young batch stops paying the
        page-rounded ``cache_len`` gather. None = full table."""
        w = self.pages_slot
        hw = max(1, min(int(hw), w))
        while w % 2 == 0 and w // 2 >= hw:
            w //= 2
        return None if w >= self.pages_slot else w

    def _serve_step(self, live_pages: Optional[int] = None):
        fn = self._serve_fns.get(live_pages)
        if fn is None:
            kw = dict(cache_len=self.cache_len, kv_format=self.kv_format,
                      attn_path=self.attn_path,
                      kv_partitions=self.kv_partitions,
                      live_pages=live_pages)
            if self.mesh is None:
                fn = jax.jit(rsteps.make_serve_step(self.cfg, **kw))
            else:
                inputs_abs = self._serve_inputs_abstract()
                self._state_shardings = shd.decode_state_shardings(
                    inputs_abs["state"], self.cfg, self.mesh)
                fn = rsteps.jit_serve_step(
                    self.cfg, self.mesh,
                    jax.eval_shape(lambda: self.params), inputs_abs, **kw)
            self._serve_fns[live_pages] = fn
        return fn

    def _chunk_step(self, live_pages: Optional[int] = None):
        fn = self._chunk_fns.get(live_pages)
        if fn is None:
            C = self.prefill_chunk
            kw = dict(kv_format=self.kv_format,
                      attn_path=self.prefill_attn_path,
                      kv_partitions=self.prefill_kv_partitions,
                      live_pages=live_pages)
            if self.mesh is None:
                fn = jax.jit(
                    rsteps.make_prefill_chunk_step(
                        self.cfg, self.cache_len, **kw),
                    donate_argnums=(1,))
            else:
                inputs_abs = {
                    "state": jax.eval_shape(self._init_state),
                    "h": jax.ShapeDtypeStruct((1, C, self.cfg.d_model),
                                              self.cfg.dtype),
                    "positions": jax.ShapeDtypeStruct((1, C), jnp.int32),
                    "slot": jax.ShapeDtypeStruct((), jnp.int32),
                }  # "state" is split out as its own (donated) argument
                if self.paged:
                    inputs_abs["table"] = jax.ShapeDtypeStruct(
                        (1, self.pages_slot), jnp.int32)
                fn = rsteps.jit_prefill_chunk_step(
                    self.cfg, self.mesh, self.cache_len,
                    jax.eval_shape(lambda: self.params), inputs_abs, **kw)
            self._chunk_fns[live_pages] = fn
        return fn

    def _verify_step(self, live_pages: Optional[int] = None):
        """Compiled speculative-verify step: (B, spec_k+1) positions per
        call, replacing the plain decode step whenever a proposer is
        wired (a slot with no drafts just pads its row to one live
        position — byte-identical to plain decode for that slot)."""
        fn = self._verify_fns.get(live_pages)
        if fn is None:
            C = self.spec_k + 1
            kw = dict(kv_format=self.kv_format,
                      attn_path=self.verify_attn_path,
                      kv_partitions=self.verify_kv_partitions,
                      live_pages=live_pages)
            if self.mesh is None:
                fn = jax.jit(
                    rsteps.make_verify_step(self.cfg, self.cache_len,
                                            **kw),
                    donate_argnums=(1,))
            else:
                inputs_abs = {
                    "state": jax.eval_shape(self._init_state),
                    "tokens": jax.ShapeDtypeStruct((self.max_batch, C),
                                                   jnp.int32),
                    "positions": jax.ShapeDtypeStruct((self.max_batch, C),
                                                      jnp.int32),
                }
                if self.paged:
                    inputs_abs["tables"] = jax.ShapeDtypeStruct(
                        (self.max_batch, self.pages_slot), jnp.int32)
                self._state_shardings = shd.decode_state_shardings(
                    inputs_abs["state"], self.cfg, self.mesh)
                fn = rsteps.jit_verify_step(
                    self.cfg, self.mesh, self.cache_len,
                    jax.eval_shape(lambda: self.params), inputs_abs, **kw)
            self._verify_fns[live_pages] = fn
        return fn

    def _embed(self, tokens):
        if self._embed_fn is None:
            self._embed_fn = jax.jit(
                lambda p, t: layers.embed(p["embed"], t))
        return self._embed_fn(self.params, tokens)

    def _reset_carry(self, state, i: int):
        """Zero slot ``i``'s recurrent carry rows (wkv/shift/ssm …) before
        its chunked prefill starts streaming real tokens through them."""
        carry_names = ("wkv", "shift", "cm_shift", "ssm")
        cache = {k: (v.at[:, i].set(0) if k in carry_names else v)
                 for k, v in state["cache"].items()}
        return dict(state, cache=cache)

    def _insert_enc_kv(self, state, i: int, req: Request):
        """Run the audio encoder + per-layer cross K/V projections for
        ``req`` and write them into slot ``i``'s rows — the only
        whole-sequence work left outside the chunk step (it consumes the
        audio, not the prompt, so chunking does not apply)."""
        if self._encode_fn is None:
            self._encode_fn = jax.jit(
                lambda p, a: T.encode_cross_kv(p, self.cfg, a))
        ae = req.audio_embeds
        if ae is None:
            ae = jnp.zeros((self.cfg.encoder_seq, self.cfg.d_model),
                           self.cfg.dtype)
        ek, ev = self._encode_fn(self.params,
                                 jnp.asarray(ae, self.cfg.dtype)[None])
        sk, sv = state["enc_kv"]
        return dict(state, enc_kv=(
            sk.at[:, i].set(ek[:, 0].astype(sk.dtype)),
            sv.at[:, i].set(ev[:, 0].astype(sv.dtype))))

    def _apply_carry_selection(self, state, carries, sel):
        """Commit the verify step's carry checkpoints: for each row, write
        back checkpoint ``sel[b]`` — 0 restores the pre-verify carry
        (inactive rows), n commits the carry after n consumed positions
        (1 + accepted drafts). The verify step leaves the state's own
        carry leaves untouched, so this is the only writer."""
        idx = jnp.asarray(sel, jnp.int32)
        cache = dict(state["cache"])
        for name, stack in carries.items():
            ix = idx.reshape((1, -1, 1) + (1,) * (stack.ndim - 3))
            taken = jnp.take_along_axis(stack, ix, axis=2)[:, :, 0]
            cache[name] = taken.astype(cache[name].dtype)
        return dict(state, cache=cache)

    def _constrain_state(self, state):
        """Pin ``state`` back onto the decode-state shardings. The eager
        slot insert/reset/scatter ops re-commit leaves with whatever
        sharding propagation picked; the jitted steps' in_shardings refuse
        a committed mismatch, so re-place explicitly (a no-op when already
        placed right)."""
        if self.mesh is None:
            return state
        return jax.device_put(state, self._state_shardings)

    # -- paged block bookkeeping ------------------------------------------

    def _pool_map(self, state, fn):
        return jax.tree.map(
            lambda l: fn(l) if isinstance(l, kvc.PagedKVCache) else l,
            state, is_leaf=lambda x: isinstance(x, kvc.PagedKVCache))

    def _consume_reserve(self, i: int) -> None:
        self._reserve[i] = max(0, self._reserve.get(i, 0) - 1)

    def _drain_reclaimed(self, state):
        """Wipe the pos tags of blocks the allocator evicted from the warm
        set since the last drain. A warm block keeps real (published)
        content; once reclaimed it re-enters the free list and its stale
        tags would read as valid context for its next owner. Returns
        (state, device_dirty)."""
        if self.alloc is None:
            return state, False
        bids = self.alloc.take_reclaimed()
        if not bids:
            return state, False
        state = self._pool_map(
            state, lambda pool: kvc.reset_blocks(pool, bids))
        return state, True

    def _slot_alloc(self, i: int) -> int:
        """Allocate a block on slot ``i``'s behalf, consuming one unit of
        its admit-time reservation (see :meth:`_required_pages`)."""
        bid = self.alloc.alloc()
        self._consume_reserve(i)
        return bid

    def _ensure_pages(self, state, i: int, offsets, txn=None):
        """Make the pages covering logical ``offsets`` writable for slot
        ``i``: allocate unmapped pages, copy-on-write shared ones (the
        "first divergent write" of prefix sharing). Returns (state,
        device_dirty). With ``txn`` (a list), every reversible mapping
        change is recorded — ("alloc", page, bid) / ("cow", page,
        old_bid, new_bid) — so a speculative step whose drafts get
        rejected can hand the list to :meth:`_rollback_pages`."""
        tbl = self._tables[i]
        dirty = False
        for p in sorted({o // self.page_size for o in offsets}):
            bid = int(tbl[p])
            if bid < 0:
                tbl[p] = self._slot_alloc(i)
                if txn is not None:
                    txn.append(("alloc", p, int(tbl[p])))
            elif self.alloc.refcount(bid) > 1:
                new = self.alloc.cow(bid)
                self._consume_reserve(i)
                state = self._pool_map(
                    state, lambda pool: kvc.copy_blocks(pool, bid, new))
                tbl[p] = new
                dirty = True
                if txn is not None:
                    txn.append(("cow", p, bid, new))
            else:
                # exclusive owner writing in place: the block's published
                # prefix key (if any) no longer describes its bytes —
                # without this, a wrapped decode recycles its prompt pages
                # and a later identical prompt adopts destroyed content
                self.alloc.unpublish(bid)
        # allocation pressure above may have evicted warm blocks — wipe
        # their stale tags before this step's gather can see them
        state, d = self._drain_reclaimed(state)
        return state, dirty or d

    def _rollback_pages(self, state, i: int, txn, last_page: int):
        """Allocator-level rollback of a speculative step's page mappings
        beyond ``last_page`` (the page holding the last *accepted*
        position). Fresh allocations are unmapped and freed; CoW'd pages
        re-adopt the shared block (the copy is dropped before any
        divergent content was committed) — so a shared prefix is never
        left pointing at rejected-draft bytes, and in-place unpublishes
        are never re-published (their tags no longer describe the key).
        Entries at or below ``last_page`` stay: pos-tag masking keeps a
        kept page's stale tail invisible until the next window overwrites
        it. Returns (state, device_dirty)."""
        tbl = self._tables[i]
        freed = []
        for op in reversed(txn):
            if op[1] <= last_page:
                continue
            if op[0] == "alloc":
                _, p, bid = op
                tbl[p] = -1
                if self.alloc.decref(bid):
                    freed.append(bid)
            else:                               # ("cow", p, old, new)
                _, p, old, new = op
                self.alloc.incref(old)          # retake the shared ref
                tbl[p] = old
                if self.alloc.decref(new):
                    freed.append(new)
            self._reserve[i] = self._reserve.get(i, 0) + 1
        if freed:
            state = self._pool_map(
                state, lambda pool: kvc.reset_blocks(pool, freed))
            return state, True
        return state, False

    def _prefix_keys(self, req: Request):
        """(stream length, (full page keys, partial)) for ``req``, hashed
        once per request: the admit gate re-checks the queue head every
        step and admit itself needs the keys twice more — device_get'ing
        and SHA-chaining the prompt (and vision embeds) each time would
        put per-admit host latency on the serving path. Wrapping streams
        (longer than the logical window) share nothing: their offsets are
        no longer page-aligned prefix content."""
        cached = self._keys_cache.get(id(req))
        if cached is None:
            cfg = self.cfg
            if not self.share_prefix:
                # carry families compute every prompt token regardless, so
                # prefix pages are never skipped — don't pay the hashing
                S_total = len(req.prompt) + (cfg.vision_prefix or 0)
                cached = (S_total, ([], None))
                self._keys_cache[id(req)] = cached
                return cached
            pe = self._prefix_embeds(req) if cfg.vision_prefix else None
            units = kvc.position_units(req.prompt, pe)
            seed = b""
            if cfg.family == "encdec":
                # decoder K/V at every position depend on the audio via
                # cross-attention: identical prompts over different audio
                # must hash to different pages
                ae = req.audio_embeds
                if ae is None:
                    ae = jnp.zeros((cfg.encoder_seq, cfg.d_model), cfg.dtype)
                seed = np.asarray(
                    jax.device_get(jnp.asarray(ae, cfg.dtype))).tobytes()
            S_total = len(units)
            keys = kvc.page_keys(units, self.page_size, seed=seed) \
                if S_total <= self.cache_len else ([], None)
            cached = (S_total, keys)
            self._keys_cache[id(req)] = cached
        return cached

    def _try_share(self, i: int, keys) -> int:
        """Map slot ``i``'s page-aligned prompt prefix onto published
        blocks; returns how many leading positions are covered."""
        full_keys, partial = keys
        tbl = self._tables[i]
        shared = 0
        for pi, key in enumerate(full_keys):
            bid = self.alloc.lookup(key)
            if bid is None:
                return shared
            tbl[pi] = bid
            shared = (pi + 1) * self.page_size
        if partial is not None:
            key, fill = partial
            bid = self.alloc.lookup(key)
            if bid is not None:
                tbl[len(full_keys)] = bid
                shared = len(full_keys) * self.page_size + fill
        return shared

    def _publish_keys(self, i: int, slot: _Slot,
                      upto: Optional[int] = None) -> None:
        """Index slot ``i``'s prefix pages for sharing. ``upto`` (a prefill
        progress position) limits publication to *fully written* pages, so
        chunked prefill publishes incrementally — a concurrently admitted
        identical prompt adopts pages as its peer produces them."""
        full_keys, partial = slot.pf_keys
        tbl = self._tables[i]
        done = slot.pf_total if upto is None else upto
        for pi, key in enumerate(full_keys):
            if (pi + 1) * self.page_size <= done and tbl[pi] >= 0:
                self.alloc.publish(key, int(tbl[pi]))
        if partial is not None and done >= slot.pf_total \
                and tbl[len(full_keys)] >= 0:
            self.alloc.publish(partial[0], int(tbl[len(full_keys)]))

    def _share_ahead(self, i: int, slot: _Slot) -> None:
        """Adopt prefix pages published since this slot's admit (typically
        by a peer prefilling the same prompt a few chunks ahead): any
        not-yet-written page at the slot's prefill frontier whose key is
        now indexed maps to the shared block and its positions are
        skipped. At least the final position is always computed locally
        (it produces the first token's logits)."""
        full_keys, partial = slot.pf_keys
        if not full_keys and partial is None:
            return          # wrapping stream: sharing disabled, and the
                            # frontier offset may exceed the table length
        tbl = self._tables[i]
        ps = self.page_size
        while slot.pf_next < slot.pf_total - 1 and slot.pf_next % ps == 0:
            p = slot.pf_next // ps
            if tbl[p] >= 0:
                break
            if p < len(full_keys):
                bid = self.alloc.lookup(full_keys[p])
                if bid is None:
                    break
                tbl[p] = bid
                slot.pf_next = min((p + 1) * ps, slot.pf_total - 1)
            else:
                if partial is not None:
                    bid = self.alloc.lookup(partial[0])
                    if bid is not None:
                        tbl[p] = bid
                        slot.pf_next = min(p * ps + partial[1],
                                           slot.pf_total - 1)
                break

    def _required_pages(self, req: Request) -> int:
        """Worst-case new blocks this request may need over its lifetime
        (admit gate for under-provisioned pools). Shared prefix pages are
        discounted, minus one for a potential divergent-write copy — but
        only when decode cannot wrap the logical window: a wrapping decode
        may copy-on-write *every* shared page, so no discount applies."""
        if not self.paged:
            return 0
        S_total, (full_keys, partial) = self._prefix_keys(req)
        if S_total + req.max_new_tokens > self.cache_len:
            return self.pages_slot
        # count only *live* shared pages — warm pages are already counted
        # on the admit gate's supply side (pages_free + warm_pages), so
        # discounting them here would double-count and deadlock the gate
        shared = 0
        for key in full_keys:
            bid = self.alloc.peek(key)
            if bid is None or self.alloc.is_warm(bid):
                break
            shared += 1
        else:
            if partial is not None:
                bid = self.alloc.peek(partial[0])
                if bid is not None and not self.alloc.is_warm(bid):
                    shared += 1
        return self.pages_slot - max(0, shared - 1)

    def _evict_paged(self, state, i: int):
        self._reserve.pop(i, None)
        # decref may *retain* published prefix blocks warm instead of
        # freeing them (warm budget permitting) — those keep their bytes;
        # blocks the retention displaced land on the reclaimed list
        freed = [bid for bid in map(int, self._tables[i])
                 if bid >= 0 and self.alloc.decref(bid)]
        freed += self.alloc.take_reclaimed()
        self._tables[i] = -1
        if freed:
            state = self._pool_map(
                state, lambda pool: kvc.reset_blocks(pool, freed))
        return state, bool(freed)

    # -- admit paths -------------------------------------------------------

    def _flush_first_tokens(self, pending) -> None:
        """Emit the first token of every slot whose prefill completed this
        step. The prefill paths queue ``(slot, last-position logits)``
        rows here instead of argmax'ing one by one — one device-side
        argmax over the stacked rows and ONE host transfer replaces a
        per-slot sync chain."""
        if not pending:
            return
        if len(pending) == 1:
            slot, row = pending[0]
            slot.emit_first(int(jnp.argmax(row)))
            self._note_first(slot)
            self._cache_first_token(slot)
            return
        firsts = np.asarray(
            jnp.argmax(jnp.stack([row for _, row in pending]), axis=-1))
        for (slot, _), t in zip(pending, firsts):
            slot.emit_first(int(t))
            self._note_first(slot)
            self._cache_first_token(slot)

    def _cache_first_token(self, slot: _Slot) -> None:
        """Attach the freshly computed first token to the prompt's final
        chain key as allocator metadata: a later admit whose warm/live
        prefix covers the whole prompt can then skip prefill entirely —
        greedy decode makes the first token a pure function of the hashed
        prefix (prompt, vision embeds, audio seed)."""
        if not self.share_prefix:
            return
        fk = self._final_key(slot.pf_keys)
        if fk is not None and slot.tokens:
            self.alloc.set_meta(fk, int(slot.tokens[0]))

    def _note_first(self, slot: _Slot) -> None:
        """Record TTFT and queue the first token on the step's events."""
        rid = slot.req.rid
        ttft = time.perf_counter() - slot.t_admit
        if self.report is not None:
            self.report.ttft[rid] = ttft
        if self._events is not None:
            self._events.emitted.setdefault(rid, []).append(slot.tokens[-1])
        if self.metrics is not None:
            self.metrics.histogram(
                "engine_ttft_seconds",
                "admit to first token, per request").observe(ttft)

    def _final_key(self, keys) -> Optional[str]:
        """The chain key covering a prompt's *last* position — the key the
        first-token cache hangs off (a full match on it implies the whole
        prefix, vision embeds and audio seed included, matched)."""
        full_keys, partial = keys
        if partial is not None:
            return partial[0]
        return full_keys[-1] if full_keys else None

    def _admit_chunked(self, state, req: Request, i: int, t0: float,
                       pending):
        """Set up slot ``i`` for ``req`` on the chunked prefill path — the
        one admit path for every family. Returns (state, slot,
        device_dirty). The slot stays in the "prefill" phase (its chunks
        run inside the decode loop) unless the warm/live prefix covers the
        *whole* prompt and the allocator cached its first token — then the
        slot activates immediately with zero prefill steps."""
        if self.paged:
            self._reserve[i] = self._required_pages(req)
        S_total, keys = self._prefix_keys(req)
        self._keys_cache.pop(id(req), None)
        slot = _Slot(req, self.pos0(req), t0)
        slot.pf_total = S_total
        dirty = False
        shared = 0
        first_tok: Optional[int] = None
        if self.share_prefix:
            slot.pf_keys = keys
            warm_before = self.alloc.warm_pages
            shared = self._try_share(i, keys)
            warm_used = warm_before - self.alloc.warm_pages
            if self.alloc.warm_bytes > 0:
                if warm_used > 0:
                    self.report.warm_hits += 1
                else:
                    self.report.warm_misses += 1
                if self.metrics is not None:
                    self.metrics.counter(
                        "engine_warm_hits_total",
                        "admits that adopted warm prefix pages").inc(
                        1 if warm_used > 0 else 0)
                    self.metrics.counter(
                        "engine_warm_misses_total",
                        "admits that found no warm prefix pages").inc(
                        0 if warm_used > 0 else 1)
            if shared >= S_total:
                fk = self._final_key(keys)
                meta = self.alloc.meta(fk) if fk is not None else None
                if meta is not None:
                    first_tok = int(meta)
        C = self.prefill_chunk
        cold_steps = -(-S_total // C)
        if first_tok is not None:
            # full-coverage hit with a cached first token: nothing to
            # compute — the pool already holds every prompt position and
            # greedy decode from it is deterministic
            slot.pf_next = S_total
            saved = cold_steps
            slot.emit_first(first_tok)
            self._note_first(slot)
        else:
            # always compute at least the final position locally (it
            # produces the first token's logits)
            shared = min(shared, S_total - 1)
            saved = cold_steps - (-(-(S_total - shared) // C))
            emb = self._embed(jnp.asarray(req.prompt, jnp.int32)[None])[0]
            if self.cfg.vision_prefix:
                emb = jnp.concatenate(
                    [self._prefix_embeds(req), emb], axis=0)
            slot.pf_stream = emb
            slot.pf_next = shared
        if self.share_prefix:
            self.report.prefill_steps_saved += saved
            if self.metrics is not None:
                self.metrics.histogram(
                    "engine_prefill_steps_saved",
                    "chunk steps avoided per admit by shared or warm "
                    "prefix pages").observe(saved)
        if self.cfg.family in T.CARRY_FAMILIES:
            state = self._reset_carry(state, i)
            dirty = True
        if self.cfg.family == "encdec":
            state = self._insert_enc_kv(state, i, req)
            dirty = True
        return state, slot, dirty

    def _advance_prefill(self, state, i: int, slot: _Slot, pending):
        """Run one prefill chunk for slot ``i``; returns (state, dirty)."""
        C = self.prefill_chunk
        if self.paged:
            self._share_ahead(i, slot)
        start, total = slot.pf_next, slot.pf_total
        end = min(start + C, total)
        if self.paged:
            offsets = {p % self.cache_len for p in range(start, end)}
            state, dirty = self._ensure_pages(state, i, offsets)
            if dirty and self.mesh is not None:
                state = self._constrain_state(state)
        seg = slot.pf_stream[start:end]
        n = end - start
        if n < C:
            pad = jnp.zeros((C - n, seg.shape[-1]), seg.dtype)
            seg = jnp.concatenate([seg, pad], axis=0)
        positions = np.full((C,), -1, np.int32)
        positions[:n] = np.arange(start, end, dtype=np.int32)
        inputs = {
            "h": seg[None],
            "positions": jnp.asarray(positions)[None],
            "slot": jnp.asarray(i, jnp.int32),
        }
        if self.paged:
            inputs["table"] = jnp.asarray(self._tables[i:i + 1])
        lp = None
        if self.paged and self.prefill_attn_path == "gather" \
                and start < self.cache_len:
            # gather only reads pool entries < start (the chunk itself is
            # the in-flight segment), so the live high-water mark is the
            # pages holding positions 0..start-1
            lp = self._live_bucket(max(1, -(-start // self.page_size)))
        res = self._chunk_step(lp)(self.params, state, inputs)
        state = res["state"]
        slot.pf_next = end
        if end == total:
            if self.paged:
                self._publish_keys(i, slot)
            pending.append((slot, res["logits"][0]))
        elif self.paged:
            self._publish_keys(i, slot, upto=end)
        return state, False

    # -- scheduler ---------------------------------------------------------

    def pos0(self, req: Request) -> int:
        """First decode position: prompt + vision prefix (prefill wrote
        exactly that many cache entries)."""
        return int(len(req.prompt)) + (self.cfg.vision_prefix or 0)

    def _validate(self, r: Request) -> None:
        if len(r.prompt) > self.max_prompt_len:
            raise ValueError(
                f"request {r.rid}: prompt length {len(r.prompt)} exceeds "
                f"engine max_prompt_len {self.max_prompt_len}")
        if r.max_new_tokens > self.max_new_tokens:
            raise ValueError(
                f"request {r.rid}: max_new_tokens {r.max_new_tokens} "
                f"exceeds engine budget {self.max_new_tokens}")
        if r.max_new_tokens < 1:
            raise ValueError(f"request {r.rid}: max_new_tokens must be "
                             f"at least 1 (prefill emits the first token)")

    # -- re-entrant stepper API (the front door drives these directly) ----

    def start(self) -> None:
        """Arm the stepper: fresh scheduler state, empty report, initial
        decode state. Compiled steps and kernel plans are engine-lifetime
        (cached on ``self``), so a second ``start()`` reuses them — only
        per-run state resets. :meth:`run` is a wrapper over
        start/submit/step; the front door calls these directly so it can
        interleave submissions, cancellations and token streaming between
        decode steps."""
        self._waiting = collections.deque()
        self._slots = [None] * self.max_batch
        self.report = ServeReport(results={}, latencies={})
        if self.paged:
            self._tables = np.full((self.max_batch, self.pages_slot),
                                   -1, np.int32)
            self._reserve.clear()
            # the device pool is about to be re-created zeroed — warm
            # blocks' bytes are gone, so their index entries must go too
            self.alloc.purge_warm()
            self.alloc.take_reclaimed()
        if self.proposer is not None:
            self.proposer.reset(self)
        with self._ctx():
            self._state = self._init_state()
            # warm the full-table step (live-page bucket variants compile
            # lazily on first use inside _step_body)
            self._serve = self._verify_step() if self.proposer is not None \
                else self._serve_step()
        self._state_dirty = True    # needs re-placing onto the serve
                                    # shardings (set after insert/reset)
        self._tok = np.zeros(self.max_batch, np.int32)
        self._pos = np.zeros(self.max_batch, np.int32)
        self._step_no = 0
        self._events = None
        self._started = True

    def submit(self, req: Request) -> None:
        """Queue ``req`` for admission (validated now, admitted by a later
        :meth:`step` when a slot and — paged — enough pages are free)."""
        if not self._started:
            raise RuntimeError("ServingEngine.submit() before start()")
        self._validate(req)
        self._waiting.append(req)

    def cancel(self, rid: int) -> bool:
        """Cancel request ``rid`` wherever it is: drop it from the waiting
        queue, or — mid-decode / mid-chunked-prefill — evict its slot and
        decref its pages (shared blocks stay with their peers; exclusive
        blocks get their tags wiped and return to the pool). Tokens emitted
        so far land in ``report.cancelled[rid]``; the request never shows
        up in ``report.results``. Returns False if ``rid`` is not resident
        (already finished, cancelled, or never submitted). Call between
        steps — the front door applies client disconnects exactly there."""
        if not self._started:
            return False
        for idx, r in enumerate(self._waiting):
            if r.rid == rid:
                del self._waiting[idx]
                self._keys_cache.pop(id(r), None)
                self.report.cancelled[rid] = []
                self._count_cancel()
                return True
        for i, s in enumerate(self._slots):
            if s is not None and s.req.rid == rid:
                self.report.cancelled[rid] = list(s.tokens)
                if self.paged:
                    self._state, d = self._evict_paged(self._state, i)
                else:
                    self._state, d = reset_slot(self._state, i), True
                self._state_dirty |= d
                if self.proposer is not None:
                    self.proposer.evict(self, i)
                self._slots[i] = None
                self._count_cancel()
                return True
        return False

    def _count_cancel(self) -> None:
        if self.metrics is not None:
            self.metrics.counter(
                "engine_cancelled_total",
                "requests cancelled while queued or resident").inc()

    def has_work(self) -> bool:
        """True while any request is waiting or resident in a slot."""
        return self._started and (bool(self._waiting)
                                  or any(s is not None
                                         for s in self._slots))

    def drain(self, *, verbose: bool = False) -> ServeReport:
        """Step until nothing is waiting or resident; returns the report."""
        while self.has_work():
            self.step(verbose=verbose)
        return self.report

    def _next_admissible(self) -> Optional[int]:
        """Waiting-queue index of the next request to admit, or None.

        FIFO gates on the queue head (strict submission order — the
        pre-stepper engine's behavior, byte-identical for ``run()``
        callers); "priority" picks the best *arrived* request by
        (priority desc, deadline asc, arrival, rid) — the front door's
        SLO-aware admission order.
        """
        w = self._waiting
        if not w:
            return None
        if self.admission == "fifo":
            return 0 if w[0].arrival_step <= self._step_no else None
        best = None
        for idx, r in enumerate(w):
            if r.arrival_step > self._step_no:
                continue
            key = (-(r.priority or 0),
                   r.deadline_s if r.deadline_s is not None else math.inf,
                   r.arrival_step, r.rid)
            if best is None or key < best[0]:
                best = (key, idx)
        return None if best is None else best[1]

    def _finish(self, state, i: int, slot: _Slot):
        report = self.report
        rid = slot.req.rid
        report.results[rid] = slot.tokens
        report.latencies[rid] = time.perf_counter() - slot.t_admit
        if self.paged:
            state, d = self._evict_paged(state, i)
        else:
            state, d = reset_slot(state, i), True
        if self.proposer is not None:
            self.proposer.evict(self, i)
        self._slots[i] = None
        if self._events is not None:
            self._events.finished.append(rid)
        if self.metrics is not None:
            self.metrics.histogram(
                "engine_e2e_seconds",
                "admit to finish, per request").observe(
                report.latencies[rid])
        return state, d

    def _sample_metrics(self, ev: StepEvents, decode_dt: float) -> None:
        """Per-step metrics sample (queue depth, residency, pages, rates)."""
        m = self.metrics
        if m is None:
            return
        m.counter("engine_steps_total", "scheduler steps executed").inc()
        n_tok = sum(len(v) for v in ev.emitted.values())
        if n_tok:
            m.counter("engine_tokens_total", "tokens emitted").inc(n_tok)
        if decode_dt > 0.0:
            m.histogram("engine_step_seconds",
                        "decode/verify wall time per step").observe(decode_dt)
            if n_tok:
                m.histogram("engine_token_seconds",
                            "decode wall time per emitted token").observe(
                    decode_dt / n_tok)
        m.gauge("engine_queue_depth",
                "requests waiting for a slot").set(len(self._waiting))
        m.gauge("engine_active_slots",
                "slots decoding or prefilling").set(
            sum(1 for s in self._slots if s is not None))
        if self.paged:
            m.gauge("engine_pages_in_use",
                    "live KV blocks").set(self.alloc.pages_in_use)
            m.gauge("engine_warm_pages",
                    "refcount-0 prefix blocks retained warm").set(
                self.alloc.warm_pages)
        # which decode-attention path served this step (planner outcome,
        # surfaced on GET /metrics): 0=ring, 1=gather, 2=fused
        m.gauge("engine_attn_path",
                "decode attention path (0=ring 1=gather 2=fused)").set(
            {"ring": 0, "gather": 1, "fused": 2}.get(self.attn_path, -1))
        m.counter(f"engine_attn_path_steps_{self.attn_path}",
                  "scheduler steps served by this attention path").inc()
        path_code = {"ring": 0, "gather": 1, "fused": 2}
        if self.chunked:
            m.gauge("engine_prefill_attn_path",
                    "chunked-prefill attention path "
                    "(0=ring 1=gather 2=fused)").set(
                path_code.get(self.prefill_attn_path, -1))
        if self.proposer is not None:
            m.gauge("engine_verify_attn_path",
                    "speculative-verify attention path "
                    "(0=ring 1=gather 2=fused)").set(
                path_code.get(self.verify_attn_path, -1))
        if self.proposer is not None and self.report is not None:
            m.gauge("engine_acceptance_rate",
                    "accepted/proposed draft tokens").set(
                self.report.acceptance_rate)

    def step(self, *, verbose: bool = False) -> StepEvents:
        """One scheduler iteration: admit arrived requests into free slots,
        advance at most one prefill chunk per prefilling slot, run one
        batched decode (or speculative verify) step over the active slots,
        evict finished slots. Returns the step's :class:`StepEvents` so a
        caller can stream tokens per step; ``worked=False`` means nothing
        was resident and the step counter did not advance."""
        if not self._started:
            raise RuntimeError("ServingEngine.step() before start()")
        ev = StepEvents(step=self._step_no)
        if not self.has_work():
            ev.worked = False
            return ev
        self._events = ev
        try:
            with self._ctx():
                decode_dt = self._step_body(ev, verbose)
        finally:
            self._events = None
        self.report.steps = self._step_no
        self.last_state = self._state
        self._sample_metrics(ev, decode_dt)
        return ev

    def _step_body(self, ev: StepEvents, verbose: bool) -> float:
        report = self.report
        slots = self._slots
        proposer = self.proposer
        state = self._state
        state_dirty = self._state_dirty
        tok, pos = self._tok, self._pos
        step = self._step_no
        decode_dt = 0.0
        pending: List[Any] = []     # (slot, logits) rows awaiting
                                    # their batched first argmax
        # -- admit arrived requests into free slots ----------------
        admitted = 0
        for i in range(self.max_batch):
            idx = self._next_admissible()
            if idx is None:
                break
            if slots[i] is not None:
                continue
            cand = self._waiting[idx]
            if self.paged and (
                    self._required_pages(cand)
                    + sum(self._reserve.values())
                    > self.alloc.pages_free + self.alloc.warm_pages):
                break               # pool too full — wait for evicts
                                    # (warm pages count as supply: the
                                    # allocator reclaims them on demand)
            del self._waiting[idx]
            req = cand
            t0 = time.perf_counter()
            if self.chunked:
                state, slot, d = self._admit_chunked(
                    state, req, i, t0, pending)
                state_dirty |= d
            else:
                inputs = self._prefill_inputs(req)
                logits, rstate = self._prefill_fn(inputs)(
                    self.params, inputs)
                state = insert_slot(state, rstate, i)
                state_dirty = True
                slot = _Slot(req, self.pos0(req), t0)
                pending.append((slot, logits[0]))
            if proposer is not None:
                slot.prompt_ids = [
                    int(t) for t in
                    np.asarray(req.prompt).reshape(-1)]
                proposer.admit(self, i, slot)
            report.prefill_s += time.perf_counter() - t0
            report.admitted += 1
            slots[i] = slot
            ev.admitted.append(req.rid)
            admitted += 1
        if admitted and self.metrics is not None:
            self.metrics.counter(
                "engine_admitted_total",
                "requests admitted into a slot").inc(admitted)

        # -- advance chunked prefills ------------------------------
        # (pf_stream gates out warm full-hit slots, which activated
        # at admit with nothing left to compute)
        for i, s in enumerate(slots):
            if s is not None and s.phase == "prefill" \
                    and s.pf_stream is not None:
                t0 = time.perf_counter()
                if state_dirty:
                    state = self._constrain_state(state)
                    state_dirty = False
                state, d = self._advance_prefill(state, i, s,
                                                 pending)
                state_dirty |= d
                report.prefill_s += time.perf_counter() - t0
        self._flush_first_tokens(pending)

        # -- settle freshly-activated slots ------------------------
        for i, s in enumerate(slots):
            if s is not None and s.phase == "active" and \
                    len(s.tokens) == 1 and s.remaining >= 0:
                if s.remaining == 0:
                    state, d = self._finish(state, i, s)
                    state_dirty |= d
                else:
                    tok[i], pos[i] = s.tokens[0], s.pos_next

        active = [i for i, s in enumerate(slots)
                  if s is not None and s.phase == "active"]
        if not active:
            self._state, self._state_dirty = state, state_dirty
            if self.has_work():
                self._step_no = step + 1
            return decode_dt

        # -- speculative: propose → verify → accept → rollback -----
        if proposer is not None:
            k = self.spec_k
            views = [spec.ProposalView(
                i, slots[i].prompt_ids + slots[i].tokens,
                int(pos[i])) for i in active]
            t0 = time.perf_counter()
            proposals = proposer.propose(views, k)
            C = k + 1
            ptok = np.zeros((self.max_batch, C), np.int32)
            ppos = np.full((self.max_batch, C), -1, np.int32)
            n_drafts: Dict[int, int] = {}
            txns: Dict[int, list] = {}
            for i in active:
                s = slots[i]
                props = list(proposals.get(i, []))[:k]
                # clamp: (a) never emit past the request budget,
                # (b) never let the draft overhang wrap the logical
                # window — a wrapped speculative write would destroy
                # a still-in-window entry, where plain decode only
                # ever overwrites the exactly-expiring one
                n = min(len(props), s.remaining - 1)
                if int(pos[i]) + n >= self.cache_len:
                    n = max(0, self.cache_len - 1 - int(pos[i]))
                n_drafts[i] = n
                report.proposed_tokens += n
                ptok[i, 0], ppos[i, 0] = tok[i], pos[i]
                for j in range(n):
                    ptok[i, j + 1] = int(props[j])
                    ppos[i, j + 1] = int(pos[i]) + j + 1
                txns[i] = []
                if self.paged:
                    state, d = self._ensure_pages(
                        state, i,
                        [p % self.cache_len for p in
                         range(int(pos[i]), int(pos[i]) + n + 1)],
                        txn=txns[i])
                    state_dirty |= d
            if self.paged:
                report.peak_pages = max(report.peak_pages,
                                        self.alloc.pages_in_use)
            if state_dirty:
                state = self._constrain_state(state)
                state_dirty = False
            vinputs = {
                "tokens": jnp.asarray(ptok),
                "positions": jnp.asarray(ppos),
            }
            if self.paged:
                step_tables = self._tables.copy()
                for i, s in enumerate(slots):
                    if s is None or s.phase != "active":
                        step_tables[i] = -1
                vinputs["tables"] = jnp.asarray(step_tables)
            lp = None
            if self.paged and self.verify_attn_path == "gather":
                mx = max(int(pos[i]) for i in active)
                if mx + k < self.cache_len:
                    # gather reads pool entries < positions[:, 0] only
                    # (the k+1 in-flight rows are the segment), so the
                    # live high-water mark is ceil(max_pos / page_size)
                    lp = self._live_bucket(
                        max(1, -(-mx // self.page_size)))
            res = self._verify_step(lp)(self.params, state, vinputs)
            state = res["state"]
            nxt = np.asarray(res["next"])          # (B, C)
            dt = time.perf_counter() - t0
            report.decode_s += dt
            decode_dt = dt
            emitted_total = 0
            # exact greedy acceptance: draft j survives iff it equals
            # the target's own argmax at position j-1; the first
            # mismatch position contributes the target's choice as the
            # bonus token
            accepted: Dict[int, int] = {}
            for i in active:
                a = 0
                while a < n_drafts[i] and \
                        int(ptok[i, a + 1]) == int(nxt[i, a]):
                    a += 1
                accepted[i] = a
            carries = res.get("carries")
            if carries is not None:
                # recurrent families: commit each row's carry at its
                # accepted frontier (checkpoint 1 + accepted consumed
                # positions; 0 restores inactive rows untouched)
                sel = np.zeros(self.max_batch, np.int32)
                for i in active:
                    sel[i] = accepted[i] + 1
                state = self._apply_carry_selection(state, carries, sel)
                state_dirty = True
            for i in active:
                s = slots[i]
                a = accepted[i]
                emitted = [int(nxt[i, j]) for j in range(a + 1)]
                report.accepted_tokens += a
                if self.paged:
                    state, d = self._rollback_pages(
                        state, i, txns[i],
                        ((int(pos[i]) + a) % self.cache_len)
                        // self.page_size)
                    state_dirty |= d
                emitted_total += len(emitted)
                s.tokens.extend(emitted)
                ev.emitted.setdefault(s.req.rid, []).extend(emitted)
                s.remaining -= len(emitted)
                s.pos_next += len(emitted)
                tok[i], pos[i] = emitted[-1], s.pos_next
                if s.remaining == 0:
                    state, d = self._finish(state, i, s)
                    state_dirty |= d
            report.decode_tokens += emitted_total
            report.step_records.append({
                "step": step, "active": len(active),
                "admitted": admitted, "decode_ms": dt * 1e3,
                "emitted": emitted_total})
            if verbose:
                print(f"[engine] step {step}: active={len(active)} "
                      f"emitted={emitted_total} {dt*1e3:.2f} ms")
            self._state, self._state_dirty = state, state_dirty
            self._step_no = step + 1
            return decode_dt

        # -- one batched decode step over every slot ---------------
        if self.paged:
            for i in active:
                state, d = self._ensure_pages(
                    state, i, [int(pos[i]) % self.cache_len])
                state_dirty |= d
            report.peak_pages = max(report.peak_pages,
                                    self.alloc.pages_in_use)
        if state_dirty:
            # eager insert/reset/scatter ops re-committed leaves
            # off the serve shardings; steady-state steps skip this
            # (the serve output already carries its out_shardings)
            state = self._constrain_state(state)
            state_dirty = False
        t0 = time.perf_counter()
        inputs = {
            "state": state,
            "tokens": jnp.asarray(tok),
            "pos": jnp.asarray(pos),
        }
        if self._needs_active:
            # a decode step must not advance the recurrent carries of
            # rows that are free or still mid-chunked-prefill
            act = np.zeros(self.max_batch, bool)
            for i in active:
                act[i] = True
            inputs["active"] = jnp.asarray(act)
        if self.paged:
            # non-active rows (free, or mid-chunked-prefill) are
            # masked to -1: their stale tok/pos writes redirect to
            # the null block instead of corrupting real pages (the
            # ring engine was immune — each slot owned its row)
            step_tables = self._tables.copy()
            for i, s in enumerate(slots):
                if s is None or s.phase != "active":
                    step_tables[i] = -1
            inputs["tables"] = jnp.asarray(step_tables)
        lp = None
        if self.paged and self.attn_path == "gather":
            mx = max(int(pos[i]) for i in active)
            if mx < self.cache_len:
                # insert-before-attend: the step writes position mx and
                # reads entries <= mx, so the high water is ceil((mx+1)/ps)
                lp = self._live_bucket(-(-(mx + 1) // self.page_size))
        res = self._serve_step(lp)(self.params, inputs)
        state = res["state"]
        nxt = np.asarray(res["next"])
        dt = time.perf_counter() - t0
        report.decode_s += dt
        decode_dt = dt
        report.decode_tokens += len(active)
        report.step_records.append({
            "step": step, "active": len(active),
            "admitted": admitted, "decode_ms": dt * 1e3})
        if verbose:
            print(f"[engine] step {step}: active={len(active)} "
                  f"admitted={admitted} {dt*1e3:.2f} ms")

        # -- collect tokens; evict finished slots ------------------
        for i in active:
            s = slots[i]
            s.tokens.append(int(nxt[i]))
            ev.emitted.setdefault(s.req.rid, []).append(int(nxt[i]))
            s.remaining -= 1
            s.pos_next += 1
            tok[i], pos[i] = nxt[i], s.pos_next
            if s.remaining == 0:
                state, d = self._finish(state, i, s)
                state_dirty |= d
        self._state, self._state_dirty = state, state_dirty
        self._step_no = step + 1
        return decode_dt

    def run(self, requests, *, verbose: bool = False) -> ServeReport:
        """Serve ``requests`` to completion; returns a :class:`ServeReport`.

        A thin wrapper over the stepper: validate everything up front,
        :meth:`start`, :meth:`submit` in (arrival, rid) order, then
        :meth:`drain` — continuous batching, not static batching: neither
        a long request nor (with chunked prefill) a long *prompt* blocks
        short requests from cycling through. Byte-identical to the
        pre-stepper engine for the same request set.
        """
        for r in requests:
            self._validate(r)
        self.start()
        for r in sorted(requests, key=lambda r: (r.arrival_step, r.rid)):
            self.submit(r)
        return self.drain(verbose=verbose)
