"""Mesh-sharded serving engine: continuous batched decode over request slots.

The paper's deployment regime — decode GEMMs with small M and K ≫ N — only
materializes when a *serving loop* drives the kernels: a fixed pool of batch
slots, requests admitted and evicted per step, one jitted decode step over
the whole pool. This module provides that loop:

  :class:`Request`       — one generation request (prompt, budget, arrival).
  :class:`ServingEngine` — slot scheduler + compiled prefill/decode steps.
  :class:`ServeReport`   — per-request tokens/latency + per-step throughput.

Slot lifecycle (see docs/serving.md):

  admit   — a free slot takes the next arrived request; its prompt is
            prefilled at B=1 and the resulting decode state is written into
            the slot's row of the pooled state (the whole row, pos ring tags
            included, so a reused slot can never leak the previous
            occupant's entries).
  decode  — one ``serve_step`` over all ``max_batch`` slots; inactive slots
            compute on empty caches (every op is batch-row independent, so
            occupied rows are unaffected) and their outputs are ignored.
  evict   — a finished slot's ring tags are wiped (``cache_reset_slots``)
            and the slot returns to the free pool.

On a mesh the steps are jitted with the shardings of ``runtime/steps.py``
(params TP/FSDP-sharded, state batch- and window-sharded), and the kernel
plans are chosen **shard-local**: ``plan_for_params(..., mesh=...)`` costs
the per-rank GEMM (K/tp for row-parallel, N/tp for column-parallel — see
``kernels/planning.shard_problem``) so Split-K and tiles match the shapes
each rank actually executes.

The KV cache is sized prefix-aware (``configs.shapes.serve_cache_len``):
prefill writes ``prompt + vision_prefix`` entries and decode advances from
that position, so the ring holds ``prompt + prefix + gen`` slots.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import serve_cache_len
from repro.core import compat
from repro.core.quant import QuantizedTensor
from repro.kernels import planning
from repro.models import attention
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime import sharding as shd
from repro.runtime import steps as rsteps

__all__ = ["Request", "ServeReport", "ServingEngine",
           "insert_slot", "reset_slot"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token array; ``max_new_tokens`` counts every
    generated token including the one produced by prefill. ``arrival_step``
    simulates request arrival: the scheduler won't admit the request before
    that decode step. Prefix/audio embeddings are per-request frontends
    ((vision_prefix, d) / (encoder_seq, d)); when the arch needs them and
    the request doesn't carry them, the engine substitutes zeros.
    """

    rid: int
    prompt: Any
    max_new_tokens: int
    arrival_step: int = 0
    prefix_embeds: Any = None
    audio_embeds: Any = None


@dataclasses.dataclass
class ServeReport:
    """What a :meth:`ServingEngine.run` produced."""

    results: Dict[int, List[int]]          # rid → generated token ids
    latencies: Dict[int, float]            # rid → admit→finish seconds
    steps: int = 0
    decode_tokens: int = 0
    decode_s: float = 0.0
    prefill_s: float = 0.0
    step_records: List[dict] = dataclasses.field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0


class _Slot:
    """Mutable per-slot scheduler record."""

    __slots__ = ("req", "tokens", "remaining", "pos_next", "t_admit")

    def __init__(self, req: Request, first_token: int, pos0: int,
                 t_admit: float):
        self.req = req
        self.tokens = [first_token]
        self.remaining = req.max_new_tokens - 1
        self.pos_next = pos0
        self.t_admit = t_admit


def insert_slot(state, rstate, slot: int):
    """Write a B=1 prefilled decode state into batch slot ``slot``.

    Every decode-state leaf is (L, B, ...) — KV caches, rwkv/ssm states,
    encoder cross-attention KV — so one rule covers all families. The whole
    slot row is overwritten, ring pos tags included: a reused slot can never
    see a stale entry from its previous occupant.
    """
    return jax.tree.map(
        lambda s, r: s.at[:, slot].set(r[:, 0].astype(s.dtype)),
        state, rstate)


def reset_slot(state, slot: int):
    """Evict ``slot``: wipe its KV ring tags so the row reads as empty.

    Insertion already overwrites the full row, so this is decode hygiene —
    an evicted slot attends over nothing (uniformly masked scores) instead
    of the finished request's context while it waits for reuse.
    """
    def visit(leaf):
        if isinstance(leaf, attention.KVCache):
            return attention.cache_reset_slots(leaf, slot)
        return leaf

    return jax.tree.map(
        visit, state, is_leaf=lambda x: isinstance(x, attention.KVCache))


class ServingEngine:
    """Continuous-batching decode over ``max_batch`` request slots.

    ``mesh=None`` runs single-device (plain ``jax.jit``); with a mesh the
    prefill/serve steps are jitted with explicit shardings and the kernel
    plans are chosen shard-local (see module docstring).
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 max_batch: int = 8, max_prompt_len: int = 128,
                 max_new_tokens: int = 64, refine_plans: bool = False,
                 cache_len: Optional[int] = None):
        self.mesh = mesh
        self.max_batch = int(max_batch)
        self.max_prompt_len = int(max_prompt_len)
        self.max_new_tokens = int(max_new_tokens)
        self.cache_len = int(cache_len if cache_len is not None
                             else serve_cache_len(cfg, max_prompt_len,
                                                  max_new_tokens))
        self.plans: Dict[str, planning.KernelPlan] = {}
        if (getattr(cfg, "w4a16_strategy", "auto") == "auto"
                and getattr(cfg, "w4a16_plan", None) is None
                and any(isinstance(l, QuantizedTensor)
                        for l in jax.tree_util.tree_leaves(
                            params,
                            is_leaf=lambda t: isinstance(t, QuantizedTensor)))):
            # pre-plan the decode-regime GEMMs on the shapes each rank will
            # execute; the per-layer decisions pin the trace-time lookups
            self.plans = planning.plan_for_params(
                params, M=self.max_batch, mesh=mesh, refine=refine_plans)
            cfg = dataclasses.replace(cfg, w4a16_plan=self.plans)
        self.cfg = cfg

        with self._ctx():
            if mesh is not None:
                pshard = shd.param_shardings(
                    jax.eval_shape(lambda: params), mesh)
                params = jax.device_put(params, pshard)
        self.params = params

        self._prefill_fns: Dict[tuple, Any] = {}
        self._serve_fn = None
        self.last_state = None      # decode-state snapshot (tests/debug)

    # -- compiled steps ----------------------------------------------------

    def _ctx(self):
        return compat.set_mesh(self.mesh) if self.mesh is not None \
            else contextlib.nullcontext()

    def _prefill_inputs(self, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        inputs = {"tokens": prompt}
        cfg = self.cfg
        if cfg.vision_prefix:
            pe = req.prefix_embeds
            if pe is None:
                pe = jnp.zeros((cfg.vision_prefix, cfg.d_model), cfg.dtype)
            inputs["prefix_embeds"] = jnp.asarray(pe, cfg.dtype)[None]
        if cfg.family == "encdec":
            ae = req.audio_embeds
            if ae is None:
                ae = jnp.zeros((cfg.encoder_seq, cfg.d_model), cfg.dtype)
            inputs["audio_embeds"] = jnp.asarray(ae, cfg.dtype)[None]
        return inputs

    def _prefill_fn(self, inputs):
        key = tuple(sorted((k, v.shape) for k, v in inputs.items()))
        fn = self._prefill_fns.get(key)
        if fn is None:
            if self.mesh is None:
                fn = jax.jit(rsteps.make_prefill_step(self.cfg,
                                                      self.cache_len))
            else:
                fn = rsteps.jit_prefill_step(
                    self.cfg, self.mesh, self.cache_len,
                    jax.eval_shape(lambda: self.params),
                    jax.eval_shape(lambda: inputs))
            self._prefill_fns[key] = fn
        return fn

    def _serve_step(self):
        if self._serve_fn is None:
            if self.mesh is None:
                self._serve_fn = jax.jit(rsteps.make_serve_step(self.cfg))
            else:
                state_abs = jax.eval_shape(
                    lambda: T.init_decode_state(self.cfg, self.max_batch,
                                                self.cache_len))
                inputs_abs = {
                    "state": state_abs,
                    "tokens": jax.ShapeDtypeStruct((self.max_batch,),
                                                   jnp.int32),
                    "pos": jax.ShapeDtypeStruct((self.max_batch,), jnp.int32),
                }
                self._state_shardings = shd.decode_state_shardings(
                    state_abs, self.cfg, self.mesh)
                self._serve_fn = rsteps.jit_serve_step(
                    self.cfg, self.mesh,
                    jax.eval_shape(lambda: self.params), inputs_abs)
        return self._serve_fn

    def _constrain_state(self, state):
        """Pin ``state`` back onto the decode-state shardings. The eager
        slot insert/reset scatters re-commit leaves with whatever sharding
        propagation picked; the jitted serve step's in_shardings refuse a
        committed mismatch, so re-place explicitly (a no-op when already
        placed right)."""
        if self.mesh is None:
            return state
        return jax.device_put(state, self._state_shardings)

    # -- scheduler ---------------------------------------------------------

    def pos0(self, req: Request) -> int:
        """First decode position: prompt + vision prefix (prefill wrote
        exactly that many cache entries)."""
        return int(len(req.prompt)) + (self.cfg.vision_prefix or 0)

    def run(self, requests, *, verbose: bool = False) -> ServeReport:
        """Serve ``requests`` to completion; returns a :class:`ServeReport`.

        The scheduler admits arrived requests into free slots each step
        (prefilling them immediately), runs one batched decode step, and
        evicts finished slots — continuous batching, not static batching:
        a long request never blocks short ones from cycling through.
        """
        for r in requests:
            if len(r.prompt) > self.max_prompt_len:
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} exceeds "
                    f"engine max_prompt_len {self.max_prompt_len}")
            if r.max_new_tokens > self.max_new_tokens:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens {r.max_new_tokens} "
                    f"exceeds engine budget {self.max_new_tokens}")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.rid}: max_new_tokens must be "
                                 f"at least 1 (prefill emits the first token)")

        waiting = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_step, r.rid)))
        slots: List[Optional[_Slot]] = [None] * self.max_batch
        report = ServeReport(results={}, latencies={})

        with self._ctx():
            state = T.init_decode_state(self.cfg, self.max_batch,
                                        self.cache_len)
            state_dirty = True      # needs re-placing onto the serve
                                    # shardings (set after insert/reset)
            tok = np.zeros(self.max_batch, np.int32)
            pos = np.zeros(self.max_batch, np.int32)
            serve = self._serve_step()
            step = 0
            while waiting or any(s is not None for s in slots):
                # -- admit arrived requests into free slots ----------------
                admitted = 0
                for i in range(self.max_batch):
                    if not (waiting and waiting[0].arrival_step <= step):
                        break
                    if slots[i] is not None:
                        continue
                    req = waiting.popleft()
                    t0 = time.perf_counter()
                    inputs = self._prefill_inputs(req)
                    logits, rstate = self._prefill_fn(inputs)(
                        self.params, inputs)
                    first = int(jnp.argmax(logits[0]))
                    report.prefill_s += time.perf_counter() - t0
                    state = insert_slot(state, rstate, i)
                    state_dirty = True
                    slot = _Slot(req, first, self.pos0(req), t0)
                    if slot.remaining == 0:
                        state = reset_slot(state, i)
                        report.results[req.rid] = slot.tokens
                        report.latencies[req.rid] = \
                            time.perf_counter() - slot.t_admit
                    else:
                        slots[i] = slot
                        tok[i], pos[i] = first, slot.pos_next
                    admitted += 1
                active = [i for i, s in enumerate(slots) if s is not None]
                if not active:
                    if waiting:       # idle until the next arrival
                        step += 1
                        continue
                    break

                # -- one batched decode step over every slot ---------------
                if state_dirty:
                    # the eager insert/reset scatters re-committed leaves
                    # off the serve shardings; steady-state steps skip this
                    # (the serve output already carries its out_shardings)
                    state = self._constrain_state(state)
                    state_dirty = False
                t0 = time.perf_counter()
                res = serve(self.params, {
                    "state": state,
                    "tokens": jnp.asarray(tok),
                    "pos": jnp.asarray(pos),
                })
                state = res["state"]
                nxt = np.asarray(res["next"])
                dt = time.perf_counter() - t0
                report.decode_s += dt
                report.decode_tokens += len(active)
                report.step_records.append({
                    "step": step, "active": len(active),
                    "admitted": admitted, "decode_ms": dt * 1e3})
                if verbose:
                    print(f"[engine] step {step}: active={len(active)} "
                          f"admitted={admitted} {dt*1e3:.2f} ms")

                # -- collect tokens; evict finished slots ------------------
                for i in active:
                    s = slots[i]
                    s.tokens.append(int(nxt[i]))
                    s.remaining -= 1
                    s.pos_next += 1
                    tok[i], pos[i] = nxt[i], s.pos_next
                    if s.remaining == 0:
                        report.results[s.req.rid] = s.tokens
                        report.latencies[s.req.rid] = \
                            time.perf_counter() - s.t_admit
                        state = reset_slot(state, i)
                        state_dirty = True
                        slots[i] = None
                step += 1
            report.steps = step
            self.last_state = state
        return report
