"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Attention-free linear recurrence with per-head matrix state
``S_t = diag(w_t) S_{t-1} + k_t^T v_t`` and readout ``o_t = r_t S_t`` —
constant-size state, which is why this arch runs the 500k-token decode cell.

The heavy FLOPs are the r/k/v/g/w/output projections and channel-mix
linears — all ordinary ``layers.linear`` calls, hence W4A16-quantizable
(the recurrence itself is element-wise "vector-core" work and stays high
precision; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_rwkv_block(key, d_model: int, d_ff: int, num_heads: int, dtype):
    ks = jax.random.split(key, 8)
    lin = lambda k, di, do: layers.init_linear(k, di, do, dtype)
    return {
        "tm_r": lin(ks[0], d_model, d_model),
        "tm_k": lin(ks[1], d_model, d_model),
        "tm_v": lin(ks[2], d_model, d_model),
        "tm_g": lin(ks[3], d_model, d_model),
        "tm_w": lin(ks[4], d_model, d_model),   # data-dependent decay (Finch)
        "tm_o": lin(ks[5], d_model, d_model),
        "w_bias": jnp.full((d_model,), -6.0, jnp.float32),
        "cm_k": lin(ks[6], d_model, d_ff),
        "cm_v": lin(ks[7], d_ff, d_model),
    }


def _heads(x, H):
    *lead, d = x.shape
    return x.reshape(*lead, H, d // H)


def rwkv_state_init(batch: int, d_model: int, num_heads: int):
    hd = d_model // num_heads
    return {
        "wkv": jnp.zeros((batch, num_heads, hd, hd), jnp.float32),
        "shift": jnp.zeros((batch, d_model), jnp.float32),
        "cm_shift": jnp.zeros((batch, d_model), jnp.float32),
    }


def _proj(p, x, cfg):
    return layers.linear(p, x, cfg)


def time_mix_seq(p, x: jax.Array, state, *, num_heads: int, cfg=None,
                 valid=None, collect_states: bool = False):
    """Sequence mode: x (B, S, d) → (B, S, d), scan over time.

    ``valid`` (B, S) bool masks right-padded positions out of the carry:
    a masked step leaves ``wkv`` untouched and the returned ``shift`` is
    the last *valid* token (chunked prefill pads its final chunk; a row
    with no valid token keeps its incoming shift).

    With ``collect_states`` the per-step (post-mask) wkv states are also
    returned as a third value, shape (B, S, H, hd, hd) — the verify step
    uses them to checkpoint the carry at every draft position.
    """
    B, S, d = x.shape
    H = num_heads
    hd = d // H
    prev = jnp.concatenate([state["shift"].astype(x.dtype)[:, None], x[:, :-1]], 1)
    xm = 0.5 * (x + prev)                       # token-shift mixing
    r = _heads(_proj(p["tm_r"], xm, cfg), H).astype(jnp.float32)
    k = _heads(_proj(p["tm_k"], xm, cfg), H).astype(jnp.float32)
    v = _heads(_proj(p["tm_v"], xm, cfg), H).astype(jnp.float32)
    g = _proj(p["tm_g"], xm, cfg).astype(jnp.float32)
    w = jax.nn.softplus(
        _proj(p["tm_w"], xm, cfg).astype(jnp.float32) + p["w_bias"])
    w = jnp.exp(-w)                              # per-channel decay in (0,1)
    w = _heads(w, H)                             # (B, S, H, hd)

    def step(s, inp):
        rt, kt, vt, wt, mt = inp                 # (B,H,hd) ×4, (B,)
        s_new = s * wt[..., None] + kt[..., None] * vt[..., None, :]
        s = jnp.where(mt[:, None, None, None], s_new, s)
        # s: (B,H,hd_k,hd_v); o = r · S
        o = jnp.einsum("bhk,bhkv->bhv", rt, s_new)
        return s, (o, s) if collect_states else o

    mask = jnp.ones((B, S), bool) if valid is None else valid
    inps = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w)) + (
        mask.transpose(1, 0),)
    s_fin, ys = jax.lax.scan(step, state["wkv"], inps)
    o = (ys[0] if collect_states else ys)
    o = o.transpose(1, 0, 2, 3).reshape(B, S, d)
    o = o * jax.nn.silu(g)
    out = _proj(p["tm_o"], o.astype(x.dtype), cfg)
    if valid is None:
        shift_new = x[:, -1].astype(jnp.float32)
    else:
        last = jnp.maximum(jnp.sum(valid.astype(jnp.int32), 1) - 1, 0)
        taken = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        shift_new = jnp.where(valid.any(1)[:, None],
                              taken.astype(jnp.float32), state["shift"])
    new_state = dict(state, wkv=s_fin, shift=shift_new)
    if collect_states:
        return out, new_state, ys[1].transpose(1, 0, 2, 3, 4)
    return out, new_state


def time_mix_step(p, x: jax.Array, state, *, num_heads: int, cfg=None):
    """Decode mode: x (B, d) one token → (B, d)."""
    B, d = x.shape
    H = num_heads
    xm = 0.5 * (x + state["shift"].astype(x.dtype))
    r = _heads(_proj(p["tm_r"], xm, cfg), H).astype(jnp.float32)
    k = _heads(_proj(p["tm_k"], xm, cfg), H).astype(jnp.float32)
    v = _heads(_proj(p["tm_v"], xm, cfg), H).astype(jnp.float32)
    g = _proj(p["tm_g"], xm, cfg).astype(jnp.float32)
    w = jax.nn.softplus(
        _proj(p["tm_w"], xm, cfg).astype(jnp.float32) + p["w_bias"])
    w = _heads(jnp.exp(-w), H)
    s = state["wkv"] * w[..., None] + k[..., None] * v[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", r, s).reshape(B, d)
    o = o * jax.nn.silu(g)
    out = _proj(p["tm_o"], o.astype(x.dtype), cfg)
    new_state = dict(state, wkv=s, shift=x.astype(jnp.float32))
    return out, new_state


def channel_mix(p, x: jax.Array, prev: jax.Array, cfg=None):
    """RWKV channel-mix FFN with token shift. x, prev: (..., d)."""
    xm = 0.5 * (x + prev.astype(x.dtype))
    k = _proj(p["cm_k"], xm, cfg)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    return _proj(p["cm_v"], k, cfg)
