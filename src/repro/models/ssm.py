"""Mamba-style selective SSM head (the SSM half of Hymba's hybrid layers).

Diagonal selective state space: per channel c and state n,
    h_t = exp(dt_t * A[c,n]) * h_{t-1} + dt_t * B_t[n] * x_t[c]
    y_t = sum_n C_t[n] * h_t[c,n] + D[c] * x_t[c]
with input-dependent dt/B/C (the "selective" part). State is
(B, d_inner, ssm_state) — constant in sequence length, so hybrid archs run
the 500k decode cell. Projections are quantizable linears.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_ssm(key, d_model: int, d_inner: int, ssm_state: int, dtype):
    ks = jax.random.split(key, 5)
    return {
        "in_proj": layers.init_linear(ks[0], d_model, d_inner, dtype),
        "bc_proj": layers.init_linear(ks[1], d_model, 2 * ssm_state, dtype),
        "dt_proj": layers.init_linear(ks[2], d_model, d_inner, dtype),
        "out_proj": layers.init_linear(ks[3], d_inner, d_model, dtype),
        "A_log": jnp.log(
            jnp.broadcast_to(
                jnp.arange(1, ssm_state + 1, dtype=jnp.float32), (d_inner, ssm_state)
            )
        ),
        "D": jnp.ones((d_inner,), jnp.float32),
    }


def ssm_state_init(batch: int, d_inner: int, ssm_state: int):
    return jnp.zeros((batch, d_inner, ssm_state), jnp.float32)


def _gates(p, x, cfg):
    u = layers.linear(p["in_proj"], x, cfg).astype(jnp.float32)   # (..., d_inner)
    bc = layers.linear(p["bc_proj"], x, cfg).astype(jnp.float32)
    B, C = jnp.split(bc, 2, axis=-1)                               # (..., n)
    dt = jax.nn.softplus(
        layers.linear(p["dt_proj"], x, cfg).astype(jnp.float32) - 4.0)
    A = -jnp.exp(p["A_log"])                                       # (d_inner, n)
    return u, B, C, dt, A


def ssm_seq(p, x: jax.Array, state, cfg=None, *, valid=None,
            collect_states: bool = False):
    """x: (B, S, d_model) → (B, S, d_model), scan over time.

    ``valid`` (B, S) bool masks right-padded positions out of the carry:
    a masked step leaves ``h`` untouched (its output row is garbage and
    must not be consumed). Chunked prefill pads its final chunk to the
    chunk width, so the returned ``h_fin`` must only see real tokens.

    With ``collect_states`` the per-step (post-mask) carries are also
    returned as a third value, shape (B, S, d_inner, n) — the verify
    step uses them to checkpoint the carry at every draft position.
    """
    u, Bm, Cm, dt, A = _gates(p, x, cfg)
    if valid is None:
        valid = jnp.ones(x.shape[:2], bool)

    def step(h, inp):
        ut, bt, ct, dtt, vt = inp                  # (B,d),(B,n),(B,n),(B,d),(B,)
        da = jnp.exp(dtt[..., None] * A)           # (B, d, n)
        h_new = h * da + (dtt * ut)[..., None] * bt[:, None, :]
        h = jnp.where(vt[:, None, None], h_new, h)
        y = jnp.einsum("bdn,bn->bd", h_new, ct)
        return h, (y, h) if collect_states else y

    inps = tuple(a.transpose(1, 0, 2) for a in (u, Bm, Cm, dt)) + (
        valid.transpose(1, 0),)
    h_fin, ys = jax.lax.scan(step, state, inps)
    y = (ys[0] if collect_states else ys).transpose(1, 0, 2) + u * p["D"]
    out = layers.linear(p["out_proj"], y.astype(x.dtype), cfg)
    if collect_states:
        return out, h_fin, ys[1].transpose(1, 0, 2, 3)
    return out, h_fin


def ssm_step(p, x: jax.Array, state, cfg=None):
    """x: (B, d_model) one token."""
    u, Bm, Cm, dt, A = _gates(p, x, cfg)
    da = jnp.exp(dt[..., None] * A)
    h = state * da + (dt * u)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + u * p["D"]
    out = layers.linear(p["out_proj"], y.astype(x.dtype), cfg)
    return out, h
