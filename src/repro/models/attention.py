"""GQA attention: chunked online-softmax (train/prefill) + KV-cache decode.

The chunked path is flash-attention-style blockwise softmax written in pure
JAX (``lax.scan`` over KV chunks, query chunks folded into a batch dim) so a
32k-token prefill never materializes an S×S score matrix. Sliding-window
(SWA) masking is positional, so SWA archs keep an O(window) KV cache — which
is what makes the 500k-token decode shape feasible for them.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, W, Hkv, D)
    v: jax.Array          # (B, W, Hkv, D)
    pos: jax.Array        # (B, W) int32 absolute position of each slot, -1 empty


def init_cache(batch: int, window: int, num_kv_heads: int, head_dim: int,
               dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, window, num_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, window, num_kv_heads, head_dim), dtype),
        pos=jnp.full((batch, window), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# chunked attention (training / prefill)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_chunk", "kv_chunk")
)
def chunked_attention(
    q: jax.Array,                # (B, S, Hq, D)
    k: jax.Array,                # (B, S, Hkv, D)
    v: jax.Array,                # (B, S, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,             # 0 = full; >0 = sliding window
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv

    def _chunk(S, target):
        t = min(target, S)
        for c in range(t, 0, -1):
            if S % c == 0:
                return c
        return 1

    cq = _chunk(Sq, q_chunk)
    ck = _chunk(Skv, kv_chunk)
    nq, nk = Sq // cq, Skv // ck
    scale = D ** -0.5

    # (B, nq, cq, Hkv, G, D) — query chunks become a batch dim. Dots run in
    # the input dtype with fp32 accumulation (upcasting K/V chunks would
    # materialize f32 copies); the online-softmax state stays fp32.
    qc = (q.reshape(B, nq, cq, Hkv, G, D).astype(jnp.float32)
          * scale).astype(k.dtype)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, D)
    qpos = jnp.arange(Sq, dtype=jnp.int32).reshape(nq, cq)

    def step(carry, inputs):
        m, l, acc = carry
        kj, vj, kpos = inputs            # (B, ck, Hkv, D), (ck,)
        # scores: (B, nq, Hkv, G, cq, ck)
        s = jnp.einsum(
            "bqchgd,bkhd->bqhgck", qc, kj,
            preferred_element_type=jnp.float32,
        )
        mask = jnp.ones((nq, cq, ck), bool)
        if causal:
            mask &= kpos[None, None, :] <= qpos[:, :, None]
        if window:
            mask &= kpos[None, None, :] > qpos[:, :, None] - window
        s = jnp.where(mask[None, :, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgck,bkhd->bqhgcd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nq, Hkv, G, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, Hkv, G, cq), jnp.float32)
    a0 = jnp.zeros((B, nq, Hkv, G, cq, D), jnp.float32)
    kpos_all = jnp.arange(Skv, dtype=jnp.int32).reshape(nk, ck)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kpos_all),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B, nq, Hkv, G, cq, D) → (B, S, Hq, D)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention over a (ring-buffer) KV cache
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,                # (B, Hq, D) — one new token per sequence
    cache: KVCache,
    pos: jax.Array,              # (B,) int32 absolute position of the new token
    *,
    window: int = 0,
) -> jax.Array:
    """One-token decode attention over a pos-tagged window: exactly the
    C=1 case of :func:`prefix_chunk_attention`, kept as a wrapper so the
    masking and dtype policy exist in one place (a divergence here is the
    bug class the paged/ring parity suite exists to catch)."""
    return prefix_chunk_attention(
        q[:, None], cache, pos[:, None], window=window)[:, 0]


def prefix_chunk_attention(
    q: jax.Array,                # (B, C, Hq, D) — one prefill chunk
    cache: KVCache,              # gathered window incl. this chunk's K/V
    qpos: jax.Array,             # (B, C) absolute positions, -1 = padding
    *,
    window: int = 0,
) -> jax.Array:
    """Chunked-prefill attention: a chunk of queries over a pos-tagged
    window that already contains the chunk's own K/V (scatter-then-gather),
    so past context and intra-chunk causality fall out of one mask:
    ``kpos >= 0 & kpos <= qpos`` (+ sliding window). Padded queries
    (``qpos < 0``) produce garbage the caller ignores.

    The C × W score block is materialized directly — chunks are bounded by
    ``prefill_chunk`` (and the window length by ``cache_len``), which is
    exactly the working-set bound chunked prefill exists to enforce.
    """
    B, C, Hq, D = q.shape
    Hkv = cache.k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    # score/readout dots run in the cache dtype with fp32 accumulation —
    # upcasting the cache itself would materialize an f32 copy of the whole
    # KV window every step (2× decode HBM traffic, +12 GB/device at 405B)
    qg = (q.reshape(B, C, Hkv, G, D).astype(jnp.float32) * scale).astype(
        cache.k.dtype)
    s = jnp.einsum("bchgd,bwhd->bhgcw", qg, cache.k,
                   preferred_element_type=jnp.float32)
    kpos = cache.pos                                       # (B, W)
    valid = (kpos[:, None, :] >= 0) & \
        (kpos[:, None, :] <= qpos[:, :, None])             # (B, C, W)
    if window:
        valid &= kpos[:, None, :] > (qpos[:, :, None] - window)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(cache.v.dtype)
    out = jnp.einsum("bhgcw,bwhd->bchgd", p, cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, C, Hq, D).astype(q.dtype)


def cache_insert(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array) -> KVCache:
    """Insert one token's K/V at ring slot ``pos % W``.

    k_new/v_new: (B, Hkv, D); pos: (B,) absolute positions.
    """
    W = cache.k.shape[1]
    slot = (pos % W).astype(jnp.int32)
    b = jnp.arange(cache.k.shape[0])
    k = cache.k.at[b, slot].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[b, slot].set(v_new.astype(cache.v.dtype))
    p = cache.pos.at[b, slot].set(pos.astype(jnp.int32))
    return KVCache(k, v, p)


def cache_reset_slots(cache: KVCache, slots) -> KVCache:
    """Evict batch slot(s): mark every ring entry of those rows empty.

    ``slots``: an int or int array of batch indices. Only the pos tags are
    wiped (-1 = empty) — decode_attention masks on pos, so stale K/V bytes
    are unreachable once their tags are cleared. Works on a per-layer cache
    (B, W) or a layer-stacked one (L, B, W): the batch dim is always the
    second-to-last of ``pos``.
    """
    p = cache.pos.at[..., slots, :].set(-1)
    return KVCache(cache.k, cache.v, p)


def cache_prefill(cache: KVCache, k_seq: jax.Array, v_seq: jax.Array) -> KVCache:
    """Fill the cache with the last W tokens of a prefilled sequence.

    k_seq/v_seq: (B, S, Hkv, D). Assumes positions 0..S-1.
    """
    B, S, Hkv, D = k_seq.shape
    W = cache.k.shape[1]
    T = min(S, W)
    tail_k = k_seq[:, S - T:]
    tail_v = v_seq[:, S - T:]
    tail_pos = jnp.broadcast_to(jnp.arange(S - T, S, dtype=jnp.int32), (B, T))
    slot = (tail_pos % W).astype(jnp.int32)
    b = jnp.arange(B)[:, None]
    k = cache.k.at[b, slot].set(tail_k.astype(cache.k.dtype))
    v = cache.v.at[b, slot].set(tail_v.astype(cache.v.dtype))
    p = cache.pos.at[b, slot].set(tail_pos)
    return KVCache(k, v, p)
