"""Top-k token-choice MoE with capacity-based dispatch (Switch/Mixtral style).

Dispatch is the sort-free cumsum-rank formulation: every (token, k) pair gets
a rank within its chosen expert; pairs beyond the expert capacity are
dropped (standard capacity-factor semantics). Expert FFNs run as batched
(E, Cap, d)×(E, d, ff) matmuls, which shard cleanly: expert weights are
tensor-parallel on the ff axis by default (no all-to-all — robust at 512
devices), with expert-parallel sharding available as a config knob.

Scalability: routing/dispatch is *shard-local by construction* — tokens are
reshaped to (dp_shards, T_local, d) using the ambient mesh and the whole
dispatch/combine is vmapped over the shard dim, so every gather/scatter has
batched (local) indices and GSPMD never materializes the global token
array. Capacity is therefore per data shard, which matches how capacity
factors are used in practice (per-device buffers). Without this, a 32k
MoE prefill all-gathers 8.6 GB of tokens per layer.

Expert kernels are 3-D (E, K, N) and quantize per-expert via
``layers.quantize_tree`` — W4A16's biggest capacity win in the paper's terms,
since expert weights dominate MoE model bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.kernels import planning
from repro.models import layers


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    E = num_experts
    return {
        "router": layers.init_linear(k1, d_model, E, dtype),
        "w_gate": {"kernel": (jax.random.normal(k2, (E, d_model, d_ff), jnp.float32) * s_in).astype(dtype)},
        "w_up": {"kernel": (jax.random.normal(k3, (E, d_model, d_ff), jnp.float32) * s_in).astype(dtype)},
        "w_down": {"kernel": (jax.random.normal(k4, (E, d_ff, d_model), jnp.float32) * s_out).astype(dtype)},
    }


def _expert_matmul(w, x, cfg):
    """x: (E, Cap, K) · w: (E, K, N) — dense or per-expert W4A16."""
    kern = w["kernel"]
    if isinstance(kern, layers.QuantizedTensor):
        # one plan for the whole expert stack (all E GEMMs share shapes),
        # then vmap the planned execute over experts
        problem = planning.MatmulProblem(
            M=int(x.shape[1]), N=int(kern.packed.shape[-1]),
            K=int(x.shape[-1]), group_size=kern.group_size,
            act_dtype=str(jnp.dtype(x.dtype)),
            out_dtype=str(jnp.dtype(x.dtype)),
            has_zeros=kern.zeros is not None,
            backend=jax.default_backend(), batch=int(x.shape[0]),
            format=kern.format.name)
        plan = planning.resolve_plan(problem, cfg)
        return jax.vmap(lambda xe, qe: planning.execute(plan, xe, qe))(x, kern)
    return jnp.einsum("ecd,edf->ecf", x, kern.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _dp_axes(T: int):
    """DP axes of the ambient mesh that divide T (empty outside set_mesh)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None:
        return (), None
    axes = []
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and T % (n * mesh.shape[a]) == 0:
            axes.append(a)
            n *= mesh.shape[a]
    return tuple(axes), mesh


def _dispatch_ffn(p, xt, *, num_experts, top_k, capacity_factor, cfg):
    """Route/dispatch/combine for one token shard. xt: (T, d)."""
    T, d = xt.shape
    E = num_experts

    logits = layers.linear(p["router"], xt.astype(jnp.float32), cfg)  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(gates, top_k)                    # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(sel, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    cap = int(max(top_k, round(T * top_k / E * capacity_factor)))
    cap = min(cap, T * top_k)

    flat_e = sel.reshape(-1)                                      # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (T*k, E)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(T * top_k), flat_e]                            # pos within expert
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, E * cap)          # overflow bin

    token_id = jnp.repeat(jnp.arange(T), top_k)
    src = jnp.zeros((E * cap + 1,), jnp.int32).at[slot].set(
        token_id + 1, mode="drop")                                # 0 = empty
    src = src[: E * cap]
    gathered = jnp.where(
        (src > 0)[:, None],
        jnp.take(xt, jnp.maximum(src - 1, 0), axis=0),
        0.0,
    ).reshape(E, cap, d)

    h_gate = _expert_matmul(p["w_gate"], gathered, cfg)
    h_up = _expert_matmul(p["w_up"], gathered, cfg)
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(xt.dtype) * h_up
    out_e = _expert_matmul(p["w_down"], h, cfg).reshape(E * cap, d)

    # combine: scatter expert outputs back to (token, k) then weighted sum
    pair_out = jnp.where(
        keep[:, None],
        jnp.take(out_e, jnp.minimum(slot, E * cap - 1), axis=0),
        0.0,
    ).reshape(T, top_k, d)
    yt = jnp.sum(pair_out * weights[..., None].astype(xt.dtype), axis=1)
    return yt, aux


def moe_ffn(p, x: jax.Array, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25, cfg=None):
    """x: (..., d) → (..., d) plus aux load-balancing loss."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)                            # (T, d)
    T = xt.shape[0]

    dp, mesh = _dp_axes(T)
    manual = dp and (cfg is None or getattr(cfg, "moe_manual_dispatch", False))
    if manual:
        # dispatch is manual over the DP axes (each rank routes only its
        # local tokens — per-shard capacity, no global token gather); the
        # "model" axis stays auto so TP expert weights partition as usual.
        # Inference-only: XLA crashes on shard_map(partial-auto) under
        # AD+remat, so training uses the vmapped formulation below.
        from jax.sharding import PartitionSpec as P

        def local(pp, xl):
            y, a = _dispatch_ffn(
                pp, xl, num_experts=num_experts, top_k=top_k,
                capacity_factor=capacity_factor, cfg=cfg)
            return y, jax.lax.pmean(a, dp)

        yt, aux = compat.shard_map(
            local, mesh=mesh, axis_names=set(dp),
            in_specs=(P(), P(dp, None)),
            out_specs=(P(dp, None), P()),
            check_vma=False,
        )(p, xt)
    elif dp:
        # AD-safe DP-sharded dispatch: vmap over the shard dim so every
        # gather/scatter is batch-local; GSPMD keeps buffers shard-local
        shards = 1
        for a in dp:
            shards *= mesh.shape[a]
        xs = layers.shard_hint(xt.reshape(shards, T // shards, d), "bsd")
        yt, aux = jax.vmap(
            lambda xl: _dispatch_ffn(
                p, xl, num_experts=num_experts, top_k=top_k,
                capacity_factor=capacity_factor, cfg=cfg))(xs)
        yt = layers.shard_hint(yt, "bsd").reshape(T, d)
        aux = jnp.mean(aux)
    else:
        yt, aux = _dispatch_ffn(
            p, xt, num_experts=num_experts, top_k=top_k,
            capacity_factor=capacity_factor, cfg=cfg)
    return yt.reshape(*lead, d), aux
