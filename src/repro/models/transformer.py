"""Model assembly: init / forward / loss / prefill / decode for all families.

Layer parameters are stacked along a leading L axis and executed with
``jax.lax.scan`` (+ optional remat) so a 126-layer model lowers as one scanned
layer — essential for dry-run compile times and the standard structure for
pipeline-friendly HLO.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import (
    DEFAULT_KV_FORMAT, get_kv_format, kv_dequantize, kv_quantize,
)
from repro.models import attention, layers, moe, rwkv, ssm
from repro.models.config import ModelConfig
from repro.runtime import kvcache as kvc


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": layers.init_linear(k1, d, cfg.q_dim, cfg.dtype),
        "wk": layers.init_linear(k2, d, cfg.kv_dim, cfg.dtype),
        "wv": layers.init_linear(k3, d, cfg.kv_dim, cfg.dtype),
        "wo": layers.init_linear(k4, cfg.q_dim, d, cfg.dtype),
    }


def _init_mlp(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp_type == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": layers.init_linear(k1, d, ff, cfg.dtype),
            "w_up": layers.init_linear(k2, d, ff, cfg.dtype),
            "w_down": layers.init_linear(k3, ff, d, cfg.dtype),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": layers.init_linear(k1, d, ff, cfg.dtype, bias=True),
        "w_down": layers.init_linear(k2, ff, d, cfg.dtype, bias=True),
    }


def _init_norm(cfg: ModelConfig):
    if cfg.norm_type == "layernorm":
        return layers.init_layernorm(cfg.d_model, cfg.dtype)
    return layers.init_rmsnorm(cfg.d_model, cfg.dtype)


def _norm(cfg, p, x):
    if cfg.norm_type == "layernorm":
        return layers.layernorm(p, x)
    return layers.rmsnorm(p, x)


def _init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": _init_norm(cfg), "norm2": _init_norm(cfg)}
    if cfg.family in ("dense", "moe", "hybrid", "encdec"):
        p["attn"] = _init_attn(ks[0], cfg)
    if cfg.family in ("dense", "hybrid", "encdec"):
        p["mlp"] = _init_mlp(ks[1], cfg)
    if cfg.family == "moe":
        p["moe"] = moe.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts,
                                cfg.dtype)
    if cfg.family == "rwkv":
        p.pop("attn", None)
        blk = rwkv.init_rwkv_block(ks[0], cfg.d_model, cfg.d_ff,
                                   cfg.num_heads, cfg.dtype)
        p.update(blk)
    if cfg.family == "hybrid":
        p["ssm"] = ssm.init_ssm(ks[2], cfg.d_model, cfg.d_inner,
                                cfg.ssm_state, cfg.dtype)
    if cfg.family == "encdec":
        p["cross"] = _init_attn(ks[2], cfg)
        p["norm3"] = _init_norm(cfg)
    return p


def _init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "norm1": _init_norm(cfg), "norm2": _init_norm(cfg),
        "attn": _init_attn(ks[0], cfg), "mlp": _init_mlp(ks[1], cfg),
    }


def init_params(key, cfg: ModelConfig):
    """Dense (trainable) parameters; quantize with ``quantize_params``."""
    kE, kL, kH, kEnc = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(kE, cfg.padded_vocab, cfg.d_model,
                                       cfg.dtype),
        "final_norm": _init_norm(cfg),
    }
    lkeys = jax.random.split(kL, cfg.num_layers)
    params["layers"] = jax.vmap(lambda k: _init_layer(k, cfg))(lkeys)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_linear(
            kH, cfg.d_model, cfg.padded_vocab, cfg.dtype)
    if cfg.family == "encdec":
        ekeys = jax.random.split(kEnc, cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(ekeys),
            "final_norm": _init_norm(cfg),
        }
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of params — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def quantize_params(params, cfg: ModelConfig, *, format=None,
                    min_size: int = 1 << 16):
    """Serve-time quantization transform (the paper's W4A16 by default;
    ``format``/``cfg.quant_format`` selects any registered format
    model-wide). ``cfg.group_size`` only re-groups the default format — a
    non-default format's grouping lives in its own name. The single place
    that derives the format/group precedence for launchers and models."""
    from repro.core import quant
    fmt = quant.get_format(
        format or getattr(cfg, "quant_format", quant.DEFAULT_FORMAT))
    gs = cfg.group_size if fmt.name == quant.DEFAULT_FORMAT else None
    return layers.quantize_tree(params, format=fmt.name, group_size=gs,
                                min_size=min_size)


# ---------------------------------------------------------------------------
# attention sub-block (sequence mode)
# ---------------------------------------------------------------------------

def _attn_seq(p, cfg: ModelConfig, x, positions, *, causal=True, window=None,
              return_kv=False):
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = layers.shard_hint(
        layers.linear(p["wq"], x, cfg).reshape(B, S, H, D), "bshd")
    k = layers.shard_hint(
        layers.linear(p["wk"], x, cfg).reshape(B, S, Hkv, D), "bshd")
    v = layers.shard_hint(
        layers.linear(p["wv"], x, cfg).reshape(B, S, Hkv, D), "bshd")
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    w = cfg.sliding_window if window is None else window
    if getattr(cfg, "attn_impl", "chunked") == "flash":
        from repro.kernels.flash_attention import flash_attention
        o = flash_attention(q, k, v, causal=causal, window=w)
    else:
        o = attention.chunked_attention(q, k, v, causal=causal, window=w)
    out = layers.linear(p["wo"], o.reshape(B, S, H * D), cfg)
    # materialize the row-parallel partial sum HERE (bf16) — otherwise GSPMD
    # defers the all-reduce into the next norm's fp32 region (2x ICI bytes)
    out = layers.shard_hint(out, "bsd")
    if return_kv:
        return out, (k, v)
    return out


def _cross_attn_seq(p, cfg, x, enc_kv):
    B, S, _ = x.shape
    H, D = cfg.num_heads, cfg.head_dim
    q = layers.linear(p["wq"], x, cfg).reshape(B, S, H, D)
    k, v = enc_kv                                     # (B, T, Hkv, D)
    o = attention.chunked_attention(q, k, v, causal=False, window=0)
    return layers.linear(p["wo"], o.reshape(B, S, H * D), cfg)


def _mlp(p, cfg, x):
    if cfg.mlp_type == "swiglu":
        g = layers.linear(p["w_gate"], x, cfg)
        u = layers.linear(p["w_up"], x, cfg)
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)) * u
        return layers.shard_hint(layers.linear(p["w_down"], h, cfg), "bsd")
    h = layers.linear(p["w_up"], x, cfg)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return layers.shard_hint(layers.linear(p["w_down"], h, cfg), "bsd")


# ---------------------------------------------------------------------------
# sequence-mode layer bodies (train / prefill)
# ---------------------------------------------------------------------------

def _layer_seq(p, cfg: ModelConfig, h, positions, *, collect_cache, cache_len,
               enc_kv=None):
    """One decoder layer in sequence mode. Returns (h, cache_entry)."""
    h = layers.shard_hint(
        h, "bsd_sp" if getattr(cfg, "seq_parallel", False) else "bsd")
    cache_entry = None
    if cfg.family == "rwkv":
        B = h.shape[0]
        st = rwkv.rwkv_state_init(B, cfg.d_model, cfg.num_heads)
        x1 = _norm(cfg, p["norm1"], h)
        tm, st = rwkv.time_mix_seq(
            {k: p[k] for k in ("tm_r", "tm_k", "tm_v", "tm_g", "tm_w",
                               "tm_o", "w_bias")},
            x1, st, num_heads=cfg.num_heads, cfg=cfg)
        h = h + tm
        x2 = _norm(cfg, p["norm2"], h)
        prev = jnp.concatenate(
            [jnp.zeros_like(x2[:, :1]), x2[:, :-1]], axis=1)
        h = h + rwkv.channel_mix(
            {k: p[k] for k in ("cm_k", "cm_v")}, x2, prev, cfg)
        if collect_cache:
            cache_entry = dict(st, cm_shift=x2[:, -1].astype(jnp.float32))
        return h, cache_entry

    x1 = _norm(cfg, p["norm1"], h)
    if cfg.family == "hybrid":
        B = h.shape[0]
        attn_out, kv = _attn_seq(p["attn"], cfg, x1, positions, return_kv=True)
        s0 = ssm.ssm_state_init(B, cfg.d_inner, cfg.ssm_state)
        ssm_out, s_fin = ssm.ssm_seq(p["ssm"], x1, s0, cfg)
        h = h + 0.5 * (attn_out + ssm_out)
        h = h + _mlp(p["mlp"], cfg, _norm(cfg, p["norm2"], h))
        if collect_cache:
            kvcache = attention.init_cache(
                B, cache_len, cfg.num_kv_heads, cfg.head_dim, cfg.dtype)
            kvcache = attention.cache_prefill(kvcache, *kv)
            cache_entry = {"kv": kvcache, "ssm": s_fin}
        return h, cache_entry

    attn_out, kv = _attn_seq(p["attn"], cfg, x1, positions, return_kv=True)
    h = h + attn_out
    if cfg.family == "encdec":
        h = h + _cross_attn_seq(p["cross"], cfg, _norm(cfg, p["norm3"], h),
                                enc_kv)
    if cfg.family == "moe":
        y, _aux = moe.moe_ffn(
            p["moe"], _norm(cfg, p["norm2"], h),
            num_experts=cfg.num_experts, top_k=cfg.experts_per_token,
            capacity_factor=cfg.moe_capacity_factor, cfg=cfg)
        h = h + y
    else:
        h = h + _mlp(p["mlp"], cfg, _norm(cfg, p["norm2"], h))
    if collect_cache:
        B = h.shape[0]
        kvcache = attention.init_cache(
            B, cache_len, cfg.num_kv_heads, cfg.head_dim, cfg.dtype)
        cache_entry = {"kv": attention.cache_prefill(kvcache, *kv)}
    return h, cache_entry


def _encoder_forward(params, cfg: ModelConfig, audio_embeds):
    """Whisper-style encoder over stub frame embeddings (B, T, d)."""
    h = audio_embeds
    B, T, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(h, lp):
        h = layers.shard_hint(h, "bsd")
        x1 = _norm(cfg, lp["norm1"], h)
        h = h + _attn_seq(lp["attn"], cfg, x1, positions, causal=False,
                          window=0)
        h = h + _mlp(lp["mlp"], cfg, _norm(cfg, lp["norm2"], h))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"]["layers"])
    return _norm(cfg, params["encoder"]["final_norm"], h)


def encode_cross_kv(params, cfg: ModelConfig, audio_embeds):
    """Encoder forward + per-decoder-layer cross-attention K/V.

    audio_embeds: (B, T, d) → tuple of two (L, B, T, Hkv, D) stacks. The
    serving engine calls this once at admit (the enc-dec analogue of a
    recurrent family's carry init) and inserts the rows into the decode
    state; training/``forward`` consumes it inline.
    """
    enc_out = _encoder_forward(params, cfg, audio_embeds)
    B, T = enc_out.shape[:2]

    def cross_kv(lp):
        k = layers.linear(lp["cross"]["wk"], enc_out, cfg).reshape(
            B, T, cfg.num_kv_heads, cfg.head_dim)
        v = layers.linear(lp["cross"]["wv"], enc_out, cfg).reshape(
            B, T, cfg.num_kv_heads, cfg.head_dim)
        return (k, v)

    return jax.vmap(cross_kv)(params["layers"])       # (L, B, T, Hkv, D) ×2


# ---------------------------------------------------------------------------
# public: forward (train) / loss
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            prefix_embeds: Optional[jax.Array] = None,
            audio_embeds: Optional[jax.Array] = None,
            collect_cache: bool = False, cache_len: int = 0):
    """tokens: (B, S_text) → logits (B, S_total, padded_vocab) fp32.

    prefix_embeds: (B, P, d) vision patches (VLM stub frontend), prepended.
    audio_embeds:  (B, T, d) audio frames (encdec stub frontend).
    """
    h = layers.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = layers.shard_hint(h, "bsd")
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    enc_kv_stack = None
    if cfg.family == "encdec":
        enc_kv_stack = encode_cross_kv(params, cfg, audio_embeds)

    def body(h, xs):
        if cfg.family == "encdec":
            lp, ekv = xs
        else:
            lp, ekv = xs, None
        h, ce = _layer_seq(lp, cfg, h, positions,
                           collect_cache=collect_cache, cache_len=cache_len,
                           enc_kv=ekv)
        return h, ce

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params["layers"], enc_kv_stack) if cfg.family == "encdec" \
        else params["layers"]
    h, cache = jax.lax.scan(body, h, xs)
    h = _norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], h)
    else:
        logits = layers.linear(params["lm_head"], h, cfg).astype(jnp.float32)
    if collect_cache:
        extras = {"cache": cache}
        if cfg.family == "encdec":
            extras["enc_kv"] = enc_kv_stack
        return logits, extras
    return logits


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token cross entropy. batch: {tokens, labels, [embeds]}."""
    logits = forward(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("vision_embeds"),
        audio_embeds=batch.get("audio_embeds"),
    )
    labels = batch["labels"]
    P = logits.shape[1] - labels.shape[1]
    if P > 0:                                   # vision prefix positions
        logits = logits[:, P:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# public: prefill / decode (serving)
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens, *, cache_len: int,
            prefix_embeds=None, audio_embeds=None):
    """Run the full prompt; returns (last-token logits, decode state)."""
    logits, extras = forward(
        params, cfg, tokens, prefix_embeds=prefix_embeds,
        audio_embeds=audio_embeds, collect_cache=True, cache_len=cache_len)
    return logits[:, -1], extras


def decode_step(params, cfg: ModelConfig, state, tokens: jax.Array,
                pos: jax.Array, *, tables=None, active=None,
                cache_len: int = 0,
                kv_format: str = DEFAULT_KV_FORMAT,
                attn_path: str = "gather", kv_partitions=None,
                live_pages=None):
    """One decode step. tokens: (B,) int32; pos: (B,) absolute positions.

    state: {"cache": stacked per-layer cache, ["enc_kv": ...]} from prefill.
    With ``tables`` (B, pages_per_slot) the KV entries of ``state`` are
    paged block pools (``kvcache.PagedKVCache``): the new token is
    scattered at ``pos % cache_len`` and attention runs on ``attn_path`` —
    ``"gather"`` reassembles each slot's ring window then runs the
    unchanged ring attention; ``"fused"`` walks the block table inside the
    Pallas kernel (one pass, token-identical). ``active`` (B,) bool masks
    recurrent-carry writes for rows that are not decoding (a slot mid
    chunked-prefill shares the batch: a masked table already protects its
    KV pages, but rwkv/ssm carries are per-row state and would be
    clobbered by the dummy token without the mask). Returns (logits
    (B, V) fp32, new state).
    """
    h = layers.embed(params["embed"], tokens)            # (B, d)
    B = h.shape[0]
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kvfmt = get_kv_format(kv_format)

    def attn_step(lp, x, kvcache):
        q = layers.shard_hint(
            layers.linear(lp["wq"], x, cfg).reshape(B, H, D), "bhd")
        k = layers.shard_hint(
            layers.linear(lp["wk"], x, cfg).reshape(B, Hkv, D), "bhd")
        v = layers.shard_hint(
            layers.linear(lp["wv"], x, cfg).reshape(B, Hkv, D), "bhd")
        q = layers.apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = layers.apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        if tables is None:
            kvcache = attention.cache_insert(kvcache, k, v, pos)
            o = attention.decode_attention(q, kvcache, pos,
                                           window=cfg.sliding_window)
        else:
            kvcache = kvc.paged_insert(kvcache, tables, k, v, pos,
                                       cache_len=cache_len, fmt=kvfmt)
            o = kvc.paged_decode_attention(
                q, kvcache, tables, pos, window=cfg.sliding_window,
                fmt=kvfmt, out_dtype=cfg.dtype, attn_path=attn_path,
                kv_partitions=kv_partitions, live_pages=live_pages)
        return layers.linear(lp["wo"], o.reshape(B, H * D), cfg), kvcache

    def body(h, xs):
        h = layers.shard_hint(h, "bd")
        if cfg.family == "encdec":
            lp, ce, ekv = xs
        else:
            (lp, ce), ekv = xs, None
        if cfg.family == "rwkv":
            x1 = _norm(cfg, lp["norm1"], h)
            tm, st = rwkv.time_mix_step(
                {k: lp[k] for k in ("tm_r", "tm_k", "tm_v", "tm_g", "tm_w",
                                    "tm_o", "w_bias")},
                x1, ce, num_heads=cfg.num_heads, cfg=cfg)
            h = h + tm
            x2 = _norm(cfg, lp["norm2"], h)
            h = h + rwkv.channel_mix(
                {k: lp[k] for k in ("cm_k", "cm_v")}, x2,
                ce["cm_shift"], cfg)
            ce_new = dict(st, cm_shift=x2.astype(jnp.float32))
            if active is not None:
                ce_new = {
                    k: jnp.where(
                        active.reshape((-1,) + (1,) * (ce_new[k].ndim - 1)),
                        ce_new[k], ce[k])
                    for k in ce_new}
            return h, ce_new
        x1 = _norm(cfg, lp["norm1"], h)
        if cfg.family == "hybrid":
            a, kvnew = attn_step(lp["attn"], x1, ce["kv"])
            s_out, s_new = ssm.ssm_step(lp["ssm"], x1, ce["ssm"], cfg)
            if active is not None:
                s_new = jnp.where(active[:, None, None], s_new, ce["ssm"])
            h = h + 0.5 * (a + s_out)
            h = h + _mlp(lp["mlp"], cfg, _norm(cfg, lp["norm2"], h))
            return h, {"kv": kvnew, "ssm": s_new}
        a, kvnew = attn_step(lp["attn"], x1, ce["kv"])
        h = h + a
        if cfg.family == "encdec":
            x3 = _norm(cfg, lp["norm3"], h)
            q = layers.linear(lp["cross"]["wq"], x3, cfg).reshape(B, 1, H, D)
            k, v = ekv
            o = attention.chunked_attention(q, k, v, causal=False, window=0)
            h = h + layers.linear(lp["cross"]["wo"],
                                  o.reshape(B, 1, H * D), cfg)[:, 0]
        if cfg.family == "moe":
            y, _ = moe.moe_ffn(
                lp["moe"], _norm(cfg, lp["norm2"], h),
                num_experts=cfg.num_experts, top_k=cfg.experts_per_token,
                capacity_factor=cfg.moe_capacity_factor, cfg=cfg)
            h = h + y
        else:
            h = h + _mlp(lp["mlp"], cfg, _norm(cfg, lp["norm2"], h))
        return h, {"kv": kvnew}

    xs = (params["layers"], state["cache"])
    if cfg.family == "encdec":
        xs = (params["layers"], state["cache"], state["enc_kv"])
    h, new_cache = jax.lax.scan(body, h, xs)
    h = _norm(cfg, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], h)
    else:
        logits = layers.linear(params["lm_head"], h, cfg).astype(jnp.float32)
    new_state = dict(state, cache=new_cache)
    return logits, new_state


# Families whose decode state carries per-slot recurrent leaves (rwkv
# wkv/shift/cm_shift, hybrid ssm) that chunked prefill threads through
# `prefill_chunk_step` and speculative verify checkpoints per position.
# Every family chunks; this tuple only marks the ones that need carry
# plumbing (and whose carries a draft model cannot rewind).
CARRY_FAMILIES = ("rwkv", "hybrid")


def _logits_head(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], h)
    return layers.linear(params["lm_head"], h, cfg).astype(jnp.float32)


def _last_valid_row(h, positions):
    """h: (B, C, d); positions (B, C) with -1 padding → (B, d) at the last
    valid position (row 0 for fully-padded rows — callers discard them)."""
    last = jnp.maximum(
        jnp.sum((positions >= 0).astype(jnp.int32), axis=1) - 1, 0)
    return jnp.take_along_axis(
        h, last[:, None, None].astype(jnp.int32), axis=1)[:, 0]


def _ffn_seq(lp, cfg: ModelConfig, hc):
    """Post-attention FFN tail shared by the chunk/verify layer bodies."""
    if cfg.family == "moe":
        y, _aux = moe.moe_ffn(
            lp["moe"], _norm(cfg, lp["norm2"], hc),
            num_experts=cfg.num_experts, top_k=cfg.experts_per_token,
            capacity_factor=cfg.moe_capacity_factor, cfg=cfg)
        return hc + y
    return hc + _mlp(lp["mlp"], cfg, _norm(cfg, lp["norm2"], hc))


def _paged_chunk_attn(ap, cfg: ModelConfig, x1, pool, tables, positions,
                      safe_pos, *, fmt, cache_len: int, batched: bool,
                      attn_path: str = "gather", kv_partitions=None,
                      live_pages=None):
    """Self-attention for a (B, C) token window over the paged pool.

    Shared by chunked prefill (B=1, one slot table) and speculative verify
    (full batch, per-slot tables). Per layer the window's K/V are read
    from the slot pages *first*, then the chunk's own K/V attended as an
    explicit segment and scattered back — window BEFORE scatter, because
    when the stream wraps the logical window (prompt > cache_len on SWA
    archs) the chunk's offsets overwrite the oldest in-window entries,
    which this chunk's earliest queries still attend. Window entries at
    chunk positions (a sharing peer's copy of what this chunk recomputes,
    or its decode appends) are masked off to keep the softmax
    single-counted.

    ``attn_path`` picks how the window is read: ``"gather"``
    materializes it to HBM (``gather_window``, clamped to ``live_pages``
    when the caller knows the high-water mark) and runs
    ``prefix_chunk_attention`` over the concatenation; ``"fused"`` walks
    the block table inside the multi-query Pallas kernel
    (``kernels/paged_attention.fused_chunk_attention``) — one pass over
    pooled KV, no gathered copy, same masking. Returns
    (attn out (B, C, d), new pool).
    """
    B, C, _ = x1.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = layers.shard_hint(
        layers.linear(ap["wq"], x1, cfg).reshape(B, C, H, D), "bshd")
    k = layers.shard_hint(
        layers.linear(ap["wk"], x1, cfg).reshape(B, C, Hkv, D), "bshd")
    v = layers.shard_hint(
        layers.linear(ap["wv"], x1, cfg).reshape(B, C, Hkv, D), "bshd")
    q = layers.apply_rope(q, safe_pos, cfg.rope_theta)
    k = layers.apply_rope(k, safe_pos, cfg.rope_theta)
    # the chunk segment takes the same quantize→dequantize round-trip
    # as its stored copy, so intra-chunk attention sees exactly what
    # later queries will gather (a no-op for kv_fp16)
    kr = kv_dequantize(*kv_quantize(k, fmt), fmt=fmt, dtype=cfg.dtype)
    vr = kv_dequantize(*kv_quantize(v, fmt), fmt=fmt, dtype=cfg.dtype)
    if attn_path == "fused":
        from repro.kernels.paged_attention import fused_chunk_attention

        o = fused_chunk_attention(
            q, kr, vr, pool, tables, positions,
            window=cfg.sliding_window, fmt=fmt, out_dtype=cfg.dtype,
            kv_partitions=kv_partitions)
    else:
        win = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=cfg.dtype,
                                live_pages=live_pages)
        start = positions[:, :1]                      # first chunk pos
        wpos = jnp.where(win.pos < start, win.pos, -1)
        seq = attention.KVCache(
            k=jnp.concatenate([win.k, kr.astype(win.k.dtype)], axis=1),
            v=jnp.concatenate([win.v, vr.astype(win.v.dtype)], axis=1),
            pos=jnp.concatenate([wpos, positions], axis=1))
        o = attention.prefix_chunk_attention(q, seq, positions,
                                             window=cfg.sliding_window)
    if batched:
        pool = kvc.scatter_chunks(pool, tables, k, v, positions,
                                  cache_len=cache_len, fmt=fmt)
    else:
        pool = kvc.scatter_chunk(pool, tables[0], k[0], v[0], positions[0],
                                 cache_len=cache_len, fmt=fmt)
    a = layers.linear(ap["wo"], o.reshape(B, C, H * D), cfg)
    return layers.shard_hint(a, "bsd"), pool


def _tm_params(lp):
    return {k: lp[k] for k in ("tm_r", "tm_k", "tm_v", "tm_g", "tm_w",
                               "tm_o", "w_bias")}


def _cm_params(lp):
    return {k: lp[k] for k in ("cm_k", "cm_v")}


def prefill_chunk_step(params, cfg: ModelConfig, state, h: jax.Array,
                       positions: jax.Array, table=None, slot=None, *,
                       cache_len: int,
                       kv_format: str = DEFAULT_KV_FORMAT,
                       attn_path: str = "gather", kv_partitions=None,
                       live_pages=None):
    """One chunked-prefill step for one slot — the single prefill path for
    every architecture family.

    h: (1, C, d) embedding chunk (token embeds, or vision-prefix embeds for
    the leading positions — the engine builds the combined stream);
    positions: (1, C) absolute positions, -1 = padding in the final chunk;
    table: (1, T) the slot's block table (None for attention-free rwkv);
    slot: scalar int32 row index into the batched decode state — recurrent
    carries (rwkv wkv/shift/cm_shift, hybrid ssm) and enc-dec cross-KV are
    per-slot leaves, gathered with ``dynamic_slice_in_dim`` outside the
    layer scan, threaded through as scan xs/ys, and scattered back after.

    Attention families attend the window on ``attn_path`` — ``"gather"``
    materializes it and runs ``attention.prefix_chunk_attention``,
    ``"fused"`` one-passes the pooled pages in the multi-query Pallas
    kernel (see ``_paged_chunk_attn``) — then scatter the chunk's K/V
    into the slot's pages; recurrent families step their masked
    scans (``rwkv.time_mix_seq`` / ``ssm.ssm_seq`` with ``valid``), so a
    right-padded final chunk leaves the carry at the last real token.

    Note on MoE: expert-capacity dropping is computed over the routing
    batch, so chunked prefill (C tokens at a time) can drop different
    tokens than a whole-prompt pass — semantically valid but not
    bit-identical unless ``moe_capacity_factor`` is lifted to full
    capacity (dense families are token-identical at any chunk size).

    Returns (last-valid-position logits (1, V) fp32, new state).
    """
    fmt = get_kv_format(kv_format)
    B, C, _ = h.shape
    valid = positions >= 0                            # (B, C)
    safe_pos = jnp.maximum(positions, 0)
    cache = state["cache"]

    def row(leaf):
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1)

    def unrow(leaf, new):
        return jax.lax.dynamic_update_slice_in_dim(
            leaf, new.astype(leaf.dtype), slot, axis=1)

    if cfg.family == "rwkv":
        xs = (params["layers"], row(cache["wkv"]), row(cache["shift"]),
              row(cache["cm_shift"]))

        def body(hc, xs_):
            lp, wkv_l, sh_l, cm_l = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            tm, st = rwkv.time_mix_seq(
                _tm_params(lp), x1, {"wkv": wkv_l, "shift": sh_l},
                num_heads=cfg.num_heads, cfg=cfg, valid=valid)
            hc = hc + tm
            x2 = _norm(cfg, lp["norm2"], hc)
            prev = jnp.concatenate(
                [cm_l.astype(x2.dtype)[:, None], x2[:, :-1]], axis=1)
            hc = hc + rwkv.channel_mix(_cm_params(lp), x2, prev, cfg)
            last = jnp.maximum(jnp.sum(valid.astype(jnp.int32), 1) - 1, 0)
            cm_new = jnp.take_along_axis(x2, last[:, None, None], axis=1)[:, 0]
            cm_new = jnp.where(valid.any(1)[:, None],
                               cm_new.astype(jnp.float32), cm_l)
            return hc, (st["wkv"], st["shift"], cm_new)

        h, (wkv_n, sh_n, cm_n) = jax.lax.scan(body, h, xs)
        new_cache = dict(cache, wkv=unrow(cache["wkv"], wkv_n),
                         shift=unrow(cache["shift"], sh_n),
                         cm_shift=unrow(cache["cm_shift"], cm_n))
        new_state = dict(state, cache=new_cache)
    elif cfg.family == "hybrid":
        xs = (params["layers"], cache["kv"], row(cache["ssm"]))

        def body(hc, xs_):
            lp, pool, ssm_l = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            a, pool = _paged_chunk_attn(
                lp["attn"], cfg, x1, pool, table, positions, safe_pos,
                fmt=fmt, cache_len=cache_len, batched=False,
                attn_path=attn_path, kv_partitions=kv_partitions,
                live_pages=live_pages)
            s_out, s_fin = ssm.ssm_seq(lp["ssm"], x1, ssm_l, cfg, valid=valid)
            hc = hc + 0.5 * (a + s_out)
            return _ffn_seq(lp, cfg, hc), (pool, s_fin)

        h, (new_pool, ssm_n) = jax.lax.scan(body, h, xs)
        new_state = dict(state, cache=dict(cache, kv=new_pool,
                                           ssm=unrow(cache["ssm"], ssm_n)))
    elif cfg.family == "encdec":
        xs = (params["layers"], cache["kv"], row(state["enc_kv"][0]),
              row(state["enc_kv"][1]))

        def body(hc, xs_):
            lp, pool, ek_l, ev_l = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            a, pool = _paged_chunk_attn(
                lp["attn"], cfg, x1, pool, table, positions, safe_pos,
                fmt=fmt, cache_len=cache_len, batched=False,
                attn_path=attn_path, kv_partitions=kv_partitions,
                live_pages=live_pages)
            hc = hc + a
            hc = hc + _cross_attn_seq(
                lp["cross"], cfg, _norm(cfg, lp["norm3"], hc), (ek_l, ev_l))
            return _ffn_seq(lp, cfg, hc), pool

        h, new_pool = jax.lax.scan(body, h, xs)
        new_state = dict(state, cache=dict(cache, kv=new_pool))
    else:

        def body(hc, xs_):
            lp, pool = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            a, pool = _paged_chunk_attn(
                lp["attn"], cfg, x1, pool, table, positions, safe_pos,
                fmt=fmt, cache_len=cache_len, batched=False,
                attn_path=attn_path, kv_partitions=kv_partitions,
                live_pages=live_pages)
            return _ffn_seq(lp, cfg, hc + a), pool

        h, new_pool = jax.lax.scan(body, h, (params["layers"], cache["kv"]))
        new_state = dict(state, cache=dict(cache, kv=new_pool))

    h = _norm(cfg, params["final_norm"], h)
    logits = _logits_head(params, cfg, _last_valid_row(h, positions))
    return logits, new_state


def verify_step(params, cfg: ModelConfig, state, tokens: jax.Array,
                positions: jax.Array, tables=None, *,
                cache_len: int, kv_format: str = DEFAULT_KV_FORMAT,
                attn_path: str = "gather", kv_partitions=None,
                live_pages=None):
    """Batched speculative-verify step — every family.

    tokens: (B, C) int32 — per slot, the last emitted token followed by up
    to C-1 draft tokens; positions: (B, C) absolute, -1 = padding (short
    proposals, inactive rows); tables: (B, T) block tables (None for
    attention-free rwkv). One forward pass scores every position of every
    slot with the same math as chunked prefill, so greedy acceptance
    against the returned per-position argmax is token-identical to plain
    decode.

    Attention families: rejected drafts leave stale pool entries *above*
    each slot's accepted frontier; their tags exceed every later query
    position until the next verify window overwrites them, so the masks
    (``win.pos < start`` here, ``kpos <= qpos`` in decode) keep them
    invisible throughout — the engine rolls pages back at the allocator.

    Carry families can't roll back by masking — the recurrence folds every
    consumed token into one state — so their carries are *checkpointed*:
    the third return value stacks, per leaf, C+1 snapshots along a new
    axis 2 (index 0 = the incoming carry, index n = the carry after
    consuming n window positions; rwkv shift/cm_shift checkpoints are the
    per-position x1/x2 rows the decode step would have latched). The
    engine selects index ``1 + accepted`` per row (0 for inactive rows)
    and writes it back — ``state``'s own carry leaves are returned
    UNCHANGED so the selection is the only write. Third value is None for
    attention-only families.

    Returns (logits (B, C, V) fp32, new state, carries-or-None).
    """
    fmt = get_kv_format(kv_format)
    h = layers.embed(params["embed"], jnp.maximum(tokens, 0))   # (B, C, d)
    B, C, _ = h.shape
    valid = positions >= 0
    safe_pos = jnp.maximum(positions, 0)
    cache = state["cache"]
    carries = None

    if cfg.family == "rwkv":
        xs = (params["layers"], cache["wkv"], cache["shift"],
              cache["cm_shift"])

        def body(hc, xs_):
            lp, wkv_l, sh_l, cm_l = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            tm, _st, wkv_steps = rwkv.time_mix_seq(
                _tm_params(lp), x1, {"wkv": wkv_l, "shift": sh_l},
                num_heads=cfg.num_heads, cfg=cfg, valid=valid,
                collect_states=True)
            hc = hc + tm
            x2 = _norm(cfg, lp["norm2"], hc)
            prev = jnp.concatenate(
                [cm_l.astype(x2.dtype)[:, None], x2[:, :-1]], axis=1)
            hc = hc + rwkv.channel_mix(_cm_params(lp), x2, prev, cfg)
            # checkpoint n = carry after n consumed positions; the decode
            # step latches shift=x1 and cm_shift=x2 at each token
            wkv_s = jnp.concatenate([wkv_l[:, None], wkv_steps], axis=1)
            sh_s = jnp.concatenate(
                [sh_l[:, None], x1.astype(jnp.float32)], axis=1)
            cm_s = jnp.concatenate(
                [cm_l[:, None], x2.astype(jnp.float32)], axis=1)
            return hc, (wkv_s, sh_s, cm_s)

        h, (wkv_s, sh_s, cm_s) = jax.lax.scan(body, h, xs)
        carries = {"wkv": wkv_s, "shift": sh_s, "cm_shift": cm_s}
        new_state = state
    elif cfg.family == "hybrid":
        xs = (params["layers"], cache["kv"], cache["ssm"])

        def body(hc, xs_):
            lp, pool, ssm_l = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            a, pool = _paged_chunk_attn(
                lp["attn"], cfg, x1, pool, tables, positions, safe_pos,
                fmt=fmt, cache_len=cache_len, batched=True,
                attn_path=attn_path, kv_partitions=kv_partitions,
                live_pages=live_pages)
            s_out, _s_fin, s_steps = ssm.ssm_seq(
                lp["ssm"], x1, ssm_l, cfg, valid=valid, collect_states=True)
            hc = hc + 0.5 * (a + s_out)
            ssm_s = jnp.concatenate([ssm_l[:, None], s_steps], axis=1)
            return _ffn_seq(lp, cfg, hc), (pool, ssm_s)

        h, (new_pool, ssm_s) = jax.lax.scan(body, h, xs)
        carries = {"ssm": ssm_s}
        new_state = dict(state, cache=dict(cache, kv=new_pool))
    elif cfg.family == "encdec":
        xs = (params["layers"], cache["kv"], state["enc_kv"][0],
              state["enc_kv"][1])

        def body(hc, xs_):
            lp, pool, ek_l, ev_l = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            a, pool = _paged_chunk_attn(
                lp["attn"], cfg, x1, pool, tables, positions, safe_pos,
                fmt=fmt, cache_len=cache_len, batched=True,
                attn_path=attn_path, kv_partitions=kv_partitions,
                live_pages=live_pages)
            hc = hc + a
            hc = hc + _cross_attn_seq(
                lp["cross"], cfg, _norm(cfg, lp["norm3"], hc), (ek_l, ev_l))
            return _ffn_seq(lp, cfg, hc), pool

        h, new_pool = jax.lax.scan(body, h, xs)
        new_state = dict(state, cache=dict(cache, kv=new_pool))
    else:

        def body(hc, xs_):
            lp, pool = xs_
            hc = layers.shard_hint(hc, "bsd")
            x1 = _norm(cfg, lp["norm1"], hc)
            a, pool = _paged_chunk_attn(
                lp["attn"], cfg, x1, pool, tables, positions, safe_pos,
                fmt=fmt, cache_len=cache_len, batched=True,
                attn_path=attn_path, kv_partitions=kv_partitions,
                live_pages=live_pages)
            return _ffn_seq(lp, cfg, hc + a), pool

        h, new_pool = jax.lax.scan(body, h, (params["layers"], cache["kv"]))
        new_state = dict(state, cache=dict(cache, kv=new_pool))

    h = _norm(cfg, params["final_norm"], h)
    logits = _logits_head(params, cfg, h)
    return logits, new_state, carries


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int):
    """Fresh (empty) decode state — used when lowering decode shapes directly."""
    L = cfg.num_layers

    def stack(x):
        return jnp.broadcast_to(x, (L,) + x.shape)

    if cfg.family == "rwkv":
        st = rwkv.rwkv_state_init(batch, cfg.d_model, cfg.num_heads)
        cache = jax.tree.map(stack, dict(
            st, cm_shift=jnp.zeros((batch, cfg.d_model), jnp.float32)))
    else:
        kv = attention.init_cache(batch, cache_len, cfg.num_kv_heads,
                                  cfg.head_dim, cfg.dtype)
        entry = {"kv": kv}
        if cfg.family == "hybrid":
            entry["ssm"] = ssm.ssm_state_init(batch, cfg.d_inner,
                                              cfg.ssm_state)
        cache = jax.tree.map(stack, entry)
    state = {"cache": cache}
    if cfg.family == "encdec":
        state["enc_kv"] = (
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                       cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                       cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
        )
    return state


def init_paged_state(cfg: ModelConfig, batch: int, cache_len: int, *,
                     page_size: int, num_blocks: int,
                     kv_format: str = DEFAULT_KV_FORMAT):
    """Paged decode state: one shared block pool instead of per-slot rings.

    The per-layer KV entry is a :class:`kvcache.PagedKVCache` of
    ``num_blocks × page_size`` token slots (stacked over L like every other
    decode-state leaf); per-slot block tables live OUTSIDE the state — the
    engine passes them as a step input. Recurrent families (rwkv) hold no
    KV cache and fall through to the ring state unchanged; hybrid/encdec
    keep their ssm / enc_kv leaves per-slot as before.
    """
    if cfg.family == "rwkv":
        return init_decode_state(cfg, batch, cache_len)
    kvc.pages_per_slot(cache_len, page_size)       # validate the multiple
    L = cfg.num_layers

    def stack(x):
        return jnp.broadcast_to(x, (L,) + x.shape)

    pool = kvc.init_pool(num_blocks, page_size, cfg.num_kv_heads,
                         cfg.head_dim, cfg.dtype, kv_format)
    entry = {"kv": pool}
    if cfg.family == "hybrid":
        entry["ssm"] = ssm.ssm_state_init(batch, cfg.d_inner, cfg.ssm_state)
    state = {"cache": jax.tree.map(stack, entry)}
    if cfg.family == "encdec":
        state["enc_kv"] = (
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                       cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                       cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
        )
    return state
