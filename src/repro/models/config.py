"""Model/run configuration shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | rwkv | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_manual_dispatch: bool = False  # shard_map dispatch (inference only)
    ssm_state: int = 0
    ssm_expand: int = 1              # d_inner = ssm_expand * d_model
    sliding_window: int = 0          # 0 = full attention
    rope_theta: float = 1_000_000.0
    encoder_layers: int = 0
    encoder_seq: int = 0             # audio frames (stub frontend)
    vision_prefix: int = 0           # vision patch embeds (stub frontend)
    mlp_type: str = "swiglu"         # swiglu | gelu
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16

    # Quantized serving (the paper's W4A16 by default; any registered
    # QuantFormat name — see repro.core.quant.available_formats())
    quantize_serve: bool = True
    quant_format: str = "w4a16_g128"
    group_size: int = 128            # group override for the DEFAULT format
                                     # only; other formats carry their
                                     # grouping in their registered name
    w4a16_strategy: str = "auto"     # "auto" = cost-model planner; or any
                                     # name in planning.available_strategies()
    w4a16_plan: Any = None           # explicit KernelPlan override: a
                                     # planning.KernelPlan (all layers), a
                                     # {"KxN": plan} mapping (per layer), or
                                     # a KernelPlan JSON string; None = plan
                                     # via w4a16_strategy

    # training
    remat: bool = True
    attn_impl: str = "chunked"       # chunked (jnp, CPU/dry-run) | flash
                                     # (Pallas kernel — TPU deployment)
    seq_parallel: bool = False   # Megatron SP: residual sharded on S over model
    bf16_partials: bool = False      # row-parallel matmul partial sums cross
                                     # shards in bf16 (halves TP activation
                                     # all-reduce traffic; MXU still
                                     # accumulates fp32 within a shard)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def attn_free(self) -> bool:
        return self.family == "rwkv"

    def supports_long_context(self) -> bool:
        """True if decode state is O(window)/O(1) — eligible for long_500k."""
        return self.family in ("rwkv", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (total, embeddings included)."""
        d, ff, V = self.d_model, self.d_ff, self.padded_vocab
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mlp_type == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        per_layer = 0
        if self.family == "dense":
            per_layer = attn + mlp
        elif self.family == "moe":
            per_layer = attn + self.num_experts * 3 * d * ff + d * self.num_experts
        elif self.family == "rwkv":
            per_layer = 6 * d * d + 2 * d * ff
        elif self.family == "hybrid":
            ssm = (d * self.d_inner * 2 + d * 2 * self.ssm_state
                   + d * self.d_inner)
            per_layer = attn + ssm + mlp
        elif self.family == "encdec":
            per_layer = attn + mlp                      # decoder self
            per_layer += attn                           # decoder cross
        total = self.num_layers * per_layer
        if self.family == "encdec":
            total += self.encoder_layers * (attn + mlp)
        total += V * d                                  # embed
        if not self.tie_embeddings:
            total += V * d                              # lm head
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE uses top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_part = (self.param_count()
                      - self.num_layers * self.num_experts * 3 * d * ff)
        return dense_part + self.num_layers * self.experts_per_token * 3 * d * ff
