"""Base layers: (quantizable) Linear, norms, embeddings, RoPE.

Every matmul in the model zoo goes through :func:`linear`, which dispatches
on the weight leaf type: a plain array runs the dense path, a
``QuantizedTensor`` runs the paper's W4A16 kernel (strategy chosen by the
model config). ``quantize_tree`` is the serve-time transform that converts a
trained/dense checkpoint into W4A16 form.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import compat, quant
from repro.core.quant import QuantizedTensor, quantize
from repro.kernels import planning


# ---------------------------------------------------------------------------
# activation sharding hints (no-ops without an ambient mesh)
# ---------------------------------------------------------------------------

def shard_hint(x: jax.Array, kind: str) -> jax.Array:
    """Constrain activations under the ambient mesh: batch over DP axes,
    heads/features over "model" when divisible. A no-op outside jax.set_mesh
    so single-device tests and examples are unaffected.

    kinds: "bsd" (B,S,d) · "bshd" (B,S,H,D) · "bd" (B,d) · "bhd" (B,H,D)
         · "ecd" (E,cap,d) MoE dispatch buffers — capacity dim over DP axes
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    batch_axis = 1 if kind == "ecd" else 0
    B = x.shape[batch_axis]
    prod = 1
    chosen = []
    for a in dp:
        if B % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    bax = tuple(chosen) if chosen else None
    model = mesh.shape.get("model", 0) if "model" in names else 0
    spec = [None] * x.ndim
    spec[batch_axis] = bax
    if kind in ("bshd", "bhd"):
        h_axis = 2 if kind == "bshd" else 1
        if model and x.shape[h_axis] % model == 0:
            spec[h_axis] = "model"
    if kind == "bsd_sp" and x.ndim == 3:
        # Megatron sequence parallelism: residual stream sharded over the
        # model axis on the SEQUENCE dim between TP blocks — activation
        # stacks (remat) shrink by the TP degree; GSPMD inserts AG/RS at
        # the block boundaries (same bytes as the plain all-reduce).
        if model and x.shape[1] % model == 0:
            spec[1] = "model"
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False):
    scale = d_in ** -0.5
    p = {"kernel": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array, cfg=None) -> jax.Array:
    """y = x @ W (+ b); W may be dense or a QuantizedTensor (W4A16)."""
    w = p["kernel"]
    if isinstance(w, QuantizedTensor):
        y = planning.matmul(x, w, cfg=cfg)
    elif cfg is not None and getattr(cfg, "bf16_partials", False):
        # cross-shard partial sums in activation dtype (bf16): the GSPMD
        # all-reduce of row-parallel outputs moves half the bytes
        y = jnp.dot(x, w.astype(x.dtype))
    else:
        y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32)
        y = y.astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def quantize_tree(params, *, format=None, group_size: Optional[int] = None,
                  symmetric: Optional[bool] = None,
                  min_size: int = 1 << 16,
                  skip_names=("embed", "lm_head", "router", "bc_proj")):
    """Convert every eligible 2-D/3-D 'kernel' leaf to a QuantizedTensor.

    ``format`` names a registered :class:`~repro.core.quant.QuantFormat`
    (default ``w4a16_g128``); the legacy ``group_size``/``symmetric``
    kwargs derive a variant of it, so pre-format call sites are unchanged.
    3-D kernels (stacked layers or MoE experts) are quantized slice-wise via
    vmap — scales are per (layer/expert, K-group, N), matching the paper's
    per-matrix group quantization.
    """
    base = quant.resolve_format(format)
    if group_size is not None:
        base = base.with_group_size(group_size)
    if symmetric is not None:
        base = base.with_symmetric(symmetric)

    def pick_format(K: int):
        """Adaptive group size: fall back to smaller groups for odd dims
        (e.g. hymba's d_model=1600 is not 128-aligned but is 64-aligned).
        Channel/tensor granularities only need K packable."""
        if base.pack_factor > 1 and K % 2:
            return None
        if base.scale_granularity != "group":
            return base
        for g in (base.group_size, 64, 32):
            if K % g == 0:
                return base.with_group_size(g)
        return None

    def visit(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if any(s in names for s in skip_names) or "kernel" not in names:
            return leaf
        if not isinstance(leaf, jax.Array) or leaf.dtype == jnp.int8:
            return leaf
        if leaf.ndim < 2 or leaf.shape[-2] * leaf.shape[-1] < min_size:
            return leaf                  # per-matrix size, not stacked size
        fmt = pick_format(leaf.shape[-2])
        if fmt is None:
            return leaf
        qfn = lambda w: quantize(w, fmt, out_dtype=leaf.dtype)
        for _ in range(leaf.ndim - 2):   # stacked layers / experts
            qfn = jax.vmap(qfn)
        return qfn(leaf)

    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits = x @ table.T (fp32)."""
    return jnp.dot(x, p["table"].T.astype(x.dtype),
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                                  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
