"""Per-architecture run settings for the production mesh.

Microbatch counts + FSDP(ZeRO-3) + optimizer-state dtype are what make each
train cell fit 16 GB/chip HBM; ``zero2`` gathers FSDP weights once per step
instead of per microbatch (≈micro× less all-gather traffic — see
EXPERIMENTS.md §Perf) and is enabled wherever the model-sharded weight copy
fits; fsdp_serve additionally shards serving weights over the data axis
(weight-gathered decode) for 405B-class models.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.runtime.steps import TrainSettings

PRESETS = {
    # zero2 copy = params_bf16/16 ≈ 3.5 GB; micro=16 shrinks activation
    # stacks now that weight regathers are free (§Perf iter G2/G3)
    "granite-20b": TrainSettings(microbatches=16, fsdp=True, zero2=True),
    "h2o-danube-1.8b": TrainSettings(microbatches=4, fsdp=True, zero2=True),
    "starcoder2-7b": TrainSettings(microbatches=4, fsdp=True, zero2=True),
    # zero2 copy would be 50 GB — stays ZeRO-3 (§Perf iter L1)
    "llama3-405b": TrainSettings(
        microbatches=16, fsdp=True, fsdp_serve=True, opt_dtype=jnp.bfloat16),
    "internvl2-1b": TrainSettings(microbatches=4, fsdp=True, zero2=True),
    "whisper-small": TrainSettings(microbatches=4, fsdp=True, zero2=True),
    "rwkv6-7b": TrainSettings(microbatches=4, fsdp=True, zero2=True),
    # zero2 copy = 5.8 GB on top of 22.7 GB peak — not worth it here
    "mixtral-8x7b": TrainSettings(microbatches=8, fsdp=True),
    "olmoe-1b-7b": TrainSettings(microbatches=4, fsdp=True, zero2=True),
    # ssm scan ys dominate activations — more microbatches (§Perf)
    "hymba-1.5b": TrainSettings(microbatches=8, fsdp=True, zero2=True),
}


def settings_for(arch: str) -> TrainSettings:
    return PRESETS.get(arch, TrainSettings())


# ---------------------------------------------------------------------------
# serving presets: paged KV cache + chunked prefill knobs per arch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeSettings:
    """Per-arch paged-serving defaults (overridable from the serve CLI).

    ``page_size`` trades table length against fragmentation (smaller pages
    → better prefix-sharing granularity, longer tables); ``prefill_chunk``
    bounds how many prompt tokens one engine step may spend on prefill —
    chunked prefill is the single prefill path for every family (None =
    the engine default of 32); ``kv_format`` names a registered KV-cache
    format (core/quant.py). ``warm_cache_mb`` budgets the allocator's
    warm prefix retention (0 = off): released page-aligned prefix chains
    stay adoptable so a returning system prompt skips its prefill.
    ``speculate`` names a draft proposer (``runtime/speculative.py``
    registry: ``ngram`` | ``draft[:layers=N]``; None = off) and
    ``spec_k`` how many draft tokens each verify step scores.

    ``queue_depth`` bounds the front door's admission queue (requests past
    it get 429 — `runtime/frontdoor.py`) and ``deadline_s`` is the default
    per-request SLO applied when a client sends none (None = no deadline;
    an expired deadline is dropped with 408 before prefill).

    ``attn_path`` picks the paged decode-attention path (``auto`` lets
    ``kernels/planning.plan_attention`` rank gather vs fused per backend;
    a named path is validated against the engine mode).
    """

    page_size: int = 16
    prefill_chunk: Optional[int] = 32
    warm_cache_mb: float = 0.0
    kv_format: str = "kv_fp16"
    speculate: Optional[str] = None
    spec_k: int = 4
    queue_depth: int = 64
    deadline_s: Optional[float] = None
    attn_path: str = "auto"


SERVE_PRESETS = {
    # SWA: window-bounded windows are short — small pages share better
    "h2o-danube-1.8b": ServeSettings(page_size=8, prefill_chunk=32),
    # vision prefix: chunks cover patch embeds + tokens uniformly
    "internvl2-1b": ServeSettings(page_size=8, prefill_chunk=32),
    # code serving sees heavy prompt/output repetition — free ngram wins
    "starcoder2-7b": ServeSettings(speculate="ngram"),
    # recurrent / enc-dec: carries thread through the chunk step like
    # everyone else; smaller chunks keep per-step scan work bounded
    "rwkv6-7b": ServeSettings(prefill_chunk=32),
    "whisper-small": ServeSettings(prefill_chunk=32),
    "hymba-1.5b": ServeSettings(prefill_chunk=32),
    # 405B-class: big pages keep the block tables short at 32k contexts;
    # steps are expensive, so the admission queue is kept short — shed
    # load with a fast 429 instead of queueing past any realistic SLO
    "llama3-405b": ServeSettings(page_size=64, prefill_chunk=256,
                                 queue_depth=16),
}


def serve_settings_for(arch: str) -> ServeSettings:
    return SERVE_PRESETS.get(arch, ServeSettings())
