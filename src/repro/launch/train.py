"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch hymba-1.5b --reduced \
        --steps 20 --batch 8 --seq 64

Full configs target the production mesh (see dryrun.py); ``--reduced`` runs
the same code path end-to-end on local devices.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import SyntheticTokenStream
from repro.kernels import planning
from repro.launch.presets import settings_for
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import steps as rsteps
from repro.runtime.resilient import RunnerConfig, run_training


def extra_inputs(cfg, batch_size, rng):
    ex = {}
    if cfg.vision_prefix:
        ex["vision_embeds"] = jax.random.normal(
            rng, (batch_size, cfg.vision_prefix, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        ex["audio_embeds"] = jax.random.normal(
            rng, (batch_size, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return ex


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--plan-cache", default=None,
                    help="plan-cache JSON: pre-plan this model's quantized "
                         "serving GEMMs after training and persist them, so "
                         "the serve launcher starts with warm plans")
    ap.add_argument("--format", default=None,
                    help="quantization format for the post-training "
                         "serving-GEMM planning pass (any registered "
                         "QuantFormat name; default: config quant_format)")
    args = ap.parse_args(argv)

    if args.plan_cache and os.path.exists(args.plan_cache):
        if planning.load_plan_cache(args.plan_cache, tolerant=True) < 0:
            print(f"[train] plan cache {args.plan_cache} unreadable; "
                  f"replanning from scratch")

    cfg = (configs.get_reduced if args.reduced else configs.get_config)(
        args.arch)
    settings = rsteps.TrainSettings(microbatches=args.microbatches)
    opt_cfg = AdamWConfig(lr=1e-3)

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt_state = adamw_init(params, opt_cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name} ({cfg.family}) params={n_params/1e6:.2f}M "
          f"devices={jax.device_count()}")

    step_fn = jax.jit(rsteps.make_train_step(cfg, opt_cfg, settings))
    stream = SyntheticTokenStream(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch)
    ex = extra_inputs(cfg, args.batch, key)

    def batches(step):
        b = stream.batch_at(step)
        return {"batch": {**b, **ex}, "step": jnp.asarray(step, jnp.int32)}

    losses = []

    def on_metrics(step, m):
        losses.append(m["loss"])
        if step % 5 == 0:
            print(f"  step {step:4d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f}")

    t0 = time.time()
    params, opt_state, history = run_training(
        cfg=RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        train_step=step_fn, params=params, opt_state=opt_state,
        batches=batches, num_steps=args.steps, on_metrics=on_metrics)
    dt = time.time() - t0
    print(f"[train] done {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"events: {[h[0] for h in history]}")
    if args.plan_cache:
        # quantize a throwaway copy of the trained tree to enumerate the
        # serving GEMMs, plan them at decode batch M, and persist — the
        # train→quantize→serve pipeline starts serving with warm plans
        qparams = T.quantize_params(params, cfg, format=args.format,
                                    min_size=0)
        plans = planning.plan_for_params(qparams, M=args.batch)
        n = planning.save_plan_cache(args.plan_cache)
        print(f"[train] plan cache: {len(plans)} layer GEMMs planned, "
              f"{n} plans -> {args.plan_cache}")
    return losses


if __name__ == "__main__":
    main()
