import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b \
        --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --json out.json

For every cell it records compiled.memory_analysis() (proves fit),
cost_analysis() FLOPs/bytes, and the per-device collective-operand bytes
parsed from the partitioned HLO — the inputs to EXPERIMENTS.md §Roofline.
"""
import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import compat
from repro.configs import SHAPES, input_specs, skip_reason, cache_len_for
from repro.launch.mesh import make_production_mesh
from repro.launch.presets import settings_for
from repro.models import transformer as T
from repro.runtime import steps as rsteps

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_RESULT_RE = re.compile(r"=\s+(?:\()?(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")


def _split_computations(hlo_text: str):
    """Map computation name → its body text."""
    comps = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            if name:
                comps[name] = "\n".join(buf)
            name, buf = m.group(1), []
        elif name is not None:
            buf.append(line)
    if name:
        comps[name] = "\n".join(buf)
    return comps


_CONST_RE = re.compile(r"%([\w.\-]+) = s32\[\]\S* constant\((\d+)\)")
_CMP_RE = re.compile(
    r"compare\(%([\w.\-]+), %([\w.\-]+)\), direction=(LT|GT|LE|GE)")


def _trip_count(cond_text: str) -> int:
    """Loop bound from the while condition: the constant operand of the
    iteration-counter compare (NOT just any constant in the computation —
    vocab sizes etc. appear as constants too)."""
    consts = dict(_CONST_RE.findall(cond_text))
    bounds = []
    for a, b, d in _CMP_RE.findall(cond_text):
        for name in (a, b):
            if name in consts:
                c = int(consts[name])
                if c > 0:
                    bounds.append(c if d in ("LT", "GT") else c + 1)
    if bounds:
        return min(bounds)
    # compare may be fused away — conditions are tiny, so the smallest
    # positive s32[] scalar constant is the loop bound (min avoids picking
    # stray large constants)
    allc = [int(v) for v in consts.values() if int(v) > 0]
    return min(allc) if allc else 1


def _loop_multipliers(hlo_text: str) -> dict:
    """computation name → product of enclosing while trip counts."""
    comps = _split_computations(hlo_text)
    mult = {n: 1 for n in comps}
    call_re = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
    # iterate to fixpoint over nesting (few levels)
    for _ in range(6):
        for parent, body in comps.items():
            for m in _WHILE_RE.finditer(body):
                cond, wbody = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, ""))
                want = mult.get(parent, 1) * max(trips, 1)
                if wbody in mult and mult[wbody] < want:
                    mult[wbody] = want
                if cond in mult:
                    mult[cond] = max(mult[cond], mult.get(parent, 1))
            # fusion/reduce interiors inherit the caller's multiplier
            for callee in call_re.findall(body):
                if callee in mult and mult[callee] < mult.get(parent, 1):
                    mult[callee] = mult[parent]
    return mult, comps


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT )?%([\w.\-]+) = ([a-z0-9]+)\[([\d,]*)\]\S* ([a-z0-9\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call", "iota",
                   "after-all", "partition-id"}


def hlo_costs(hlo_text: str) -> dict:
    """Loop-aware FLOPs and HBM-byte estimates from partitioned HLO text.

    XLA's ``compiled.cost_analysis()`` counts each while body ONCE, so a
    126-layer scanned model is ~126× undercounted (verified on CPU). This
    walks every computation, multiplies by the enclosing while trip counts,
    and computes:
      * flops — 2 · |result| · |contracted dims| per dot op;
      * bytes — Σ (operand + result bytes) over top-level instructions
        (post-fusion HLO: fusion operands/results are the real HBM buffers).
    """
    mult, comps = _loop_multipliers(hlo_text)
    # computations invoked as fusions/reducers: their interiors live in
    # registers/VMEM, so bytes are attributed to the CALLING instruction
    fusion_called = set()
    while_bodies = set()
    for body in comps.values():
        fusion_called.update(re.findall(r"calls=%?([\w.\-]+)", body))
        fusion_called.update(re.findall(r"to_apply=%?([\w.\-]+)", body))
        for m in _WHILE_RE.finditer(body):
            while_bodies.add(m.group(2))
    flops = 0.0
    bytes_ = 0.0
    # Inside a while body the carry/working set is loop-resident (VMEM on
    # the target TPU) — HBM traffic there is the *stack* traffic: xs/ys
    # slice reads & writes, gathers/scatters, and collective results
    # (which land in HBM before the consuming op). Everything else in a
    # body is treated as on-chip reuse. Entry-level ops count in full.
    _BODY_BYTE_OPS = {"dynamic-slice", "dynamic-update-slice", "gather",
                      "scatter", "copy", "concatenate"}
    # stack accesses fused into kLoop fusions: pre-compute per-callee
    # slice-traffic so a fusion op inside a while body charges its inner
    # dynamic-(update-)slice bytes
    fusion_stack_bytes = {}
    for cname, body in comps.items():
        total = 0
        syms0 = {}
        for line in body.splitlines():
            m = _INSTR_RE.match(line)
            if m:
                syms0[m.group(1)] = (m.group(2), m.group(3))
        for line in body.splitlines():
            m = _INSTR_RE.match(line)
            if not m:
                continue
            _, dt0, dims0, op0, rest0 = m.groups()
            if op0 == "dynamic-slice":
                total += 2 * _bytes_of(dt0, dims0)
            elif op0 == "dynamic-update-slice":
                args0 = rest0.split("),")[0] if ")," in rest0 else rest0
                named = [o for o in _OPERAND_RE.findall(args0) if o in syms0]
                if len(named) >= 2:
                    total += 2 * _bytes_of(*syms0[named[1]])
        if total:
            fusion_stack_bytes[cname] = total

    _CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
    for cname, body in comps.items():
        k = mult.get(cname, 1)
        in_loop = cname in while_bodies or mult.get(cname, 1) > 1
        # symbol table: instruction name → (dtype, dims)
        syms = {}
        for line in body.splitlines():
            m = _INSTR_RE.match(line)
            if m:
                syms[m.group(1)] = (m.group(2), m.group(3))
        count_bytes = cname not in fusion_called
        for line in body.splitlines():
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, dt, dims, op, rest = m.groups()
            res_bytes = _bytes_of(dt, dims)
            if op == "dot":
                cd = _CDIMS_RE.search(line)
                lhs = _OPERAND_RE.search(rest)
                csize = 1
                if cd and lhs and lhs.group(1) in syms:
                    ldims = [int(x) for x in syms[lhs.group(1)][1].split(",")
                             if x]
                    for i in (int(x) for x in cd.group(1).split(",") if x):
                        if i < len(ldims):
                            csize *= ldims[i]
                n_res = 1
                for d in dims.split(","):
                    if d:
                        n_res *= int(d)
                flops += 2.0 * n_res * csize * k
            if count_bytes and op not in _SKIP_BYTES_OPS:
                if in_loop and op == "fusion":
                    cm = _CALLS_RE.search(line)
                    if cm and cm.group(1) in fusion_stack_bytes:
                        bytes_ += fusion_stack_bytes[cm.group(1)] * k
                    continue
                if in_loop and op not in _BODY_BYTE_OPS \
                        and op not in COLLECTIVES:
                    continue
                args = rest.split("),")[0] if ")," in rest else rest
                ops_named = [o for o in _OPERAND_RE.findall(args)
                             if o in syms]
                if op == "dynamic-update-slice" and len(ops_named) >= 2:
                    # in-place slice write: only the update region moves
                    total = 2 * _bytes_of(*syms[ops_named[1]])
                elif op == "dynamic-slice":
                    total = 2 * res_bytes
                elif op in COLLECTIVES:
                    total = 2 * res_bytes      # HBM write + consuming read
                else:
                    total = res_bytes + sum(
                        _bytes_of(*syms[o]) for o in ops_named)
                bytes_ += total * k
    return {"flops": flops, "bytes": bytes_}


def collective_bytes(hlo_text: str) -> dict:
    """Estimated per-device ICI traffic of every collective in the
    partitioned HLO, using ring-algorithm cost models on the RESULT shape:

      all-gather          R·(S-1)/S      (R = full gathered result)
      reduce-scatter      R·(S-1)        (R = scattered shard)
      all-reduce          2·R·(S-1)/S    (RS + AG)
      all-to-all          R·(S-1)/S
      collective-permute  R

    where S is the shard-group size parsed from replica_groups.
    Counted ONCE per static HLO op; ops inside while loops are multiplied
    by nothing (we report per-step traffic for a scanned layer stack via
    the loop trip count when present — see loop_multiplier note in
    EXPERIMENTS.md §Roofline).
    """
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    mult, comps = _loop_multipliers(hlo_text)
    for cname, body in comps.items():
        k = mult.get(cname, 1)
        for line in body.splitlines():
            for c in COLLECTIVES:
                if f" {c}(" in line or f" {c}-start(" in line:
                    m = _RESULT_RE.search(line)
                    if not m:
                        continue
                    r = _bytes_of(m.group(1), m.group(2))
                    g = _GROUPS_RE.search(line)
                    S = int(g.group(2)) if g else 2
                    if c == "all-gather":
                        b = r * (S - 1) // max(S, 1)
                    elif c == "reduce-scatter":
                        b = r * (S - 1)
                    elif c == "all-reduce":
                        b = 2 * r * (S - 1) // max(S, 1)
                    elif c == "all-to-all":
                        b = r * (S - 1) // max(S, 1)
                    else:
                        b = r
                    out[c] += b * k
                    counts[c] += k
                    break
    out["total"] = sum(out[c] for c in COLLECTIVES)
    out["op_counts"] = counts
    return out


def _abstract_opt_state(params_abs, opt_cfg):
    from repro.optim import adamw_init
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_abs)


def _serve_cfg(cfg):
    """Serving config: W4A16 via the XLA-fusable dequant+dot formulation —
    the Pallas fused kernel is dispatched per-shard (shard_map) on real TPU;
    for SPMD lowering the HLO-level formulation partitions identically.
    See DESIGN.md §Hardware adaptation."""
    return dataclasses.replace(cfg, w4a16_strategy="xla",
                               moe_manual_dispatch=True)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               quantized_serve: bool = True):
    """Build + lower one cell; returns (lowered, meta) or ('skip', reason)."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    skip = skip_reason(cfg, shape)
    if skip:
        return None, {"skipped": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    settings = settings_for(arch)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        # per-microbatch batch must stay DP-shardable: clamp microbatches
        # so (global_batch / micro) % dp_world == 0
        dpw = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dpw *= mesh.shape[a]
        micro = settings.microbatches
        while micro > 1 and (shape.global_batch // micro) % dpw:
            micro //= 2
        if micro != settings.microbatches:
            settings = dataclasses.replace(settings, microbatches=micro)
        params_abs = T.abstract_params(cfg)
        from repro.optim import AdamWConfig
        opt_cfg = AdamWConfig(state_dtype=settings.opt_dtype)
        opt_abs = _abstract_opt_state(params_abs, opt_cfg)
        inputs_abs = {"batch": specs["batch"],
                      "step": jax.ShapeDtypeStruct((), jnp.int32)}
        with compat.set_mesh(mesh):
            fn = rsteps.jit_train_step(cfg, mesh, settings, params_abs,
                                       inputs_abs, opt_cfg)
            lowered = fn.lower(params_abs, opt_abs, inputs_abs)
        return lowered, {"mesh": mesh, "kind": "train"}

    scfg = _serve_cfg(cfg)
    params_abs = T.abstract_params(scfg)
    if quantized_serve and scfg.quantize_serve:
        params_abs = jax.eval_shape(
            lambda p: T.quantize_params(p, scfg), params_abs)

    if shape.kind == "prefill":
        with compat.set_mesh(mesh):
            fn = rsteps.jit_prefill_step(
                scfg, mesh, cache_len_for(scfg, shape), params_abs, specs,
                fsdp_serve=settings.fsdp_serve)
            lowered = fn.lower(params_abs, specs)
        return lowered, {"mesh": mesh, "kind": "prefill"}

    with compat.set_mesh(mesh):
        fn = rsteps.jit_serve_step(scfg, mesh, params_abs, specs,
                                   fsdp_serve=settings.fsdp_serve)
        lowered = fn.lower(params_abs, specs)
    return lowered, {"mesh": mesh, "kind": "decode"}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod)
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "LOWER_FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
        return rec
    if lowered is None:
        rec["status"] = "SKIP"
        rec["skip_reason"] = meta["skipped"]
        return rec
    try:
        compiled = lowered.compile()
    except Exception as e:
        rec["status"] = "COMPILE_FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
        return rec
    rec["status"] = "OK"
    rec["kind"] = meta["kind"]
    mem = compiled.memory_analysis()
    try:
        rec["bytes_per_device"] = {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "peak_total": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes),
        }
    except AttributeError:
        rec["bytes_per_device"] = str(mem)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    rec["cost_xla_raw"] = {
        k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")}
    hlo_text = compiled.as_text()
    rec["cost"] = hlo_costs(hlo_text)        # loop-aware (see hlo_costs)
    rec["collectives"] = collective_bytes(hlo_text)
    rec["seconds"] = round(time.time() - t0, 1)
    if verbose:
        print(json.dumps(rec, indent=None, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="write records to this file")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(configs.ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    fail = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, multi_pod=mp)
                records.append(rec)
                if rec["status"] not in ("OK", "SKIP"):
                    fail += 1
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1, default=str)
    ok = sum(r["status"] == "OK" for r in records)
    sk = sum(r["status"] == "SKIP" for r in records)
    print(f"\n== dry-run: {ok} OK, {sk} skipped, {fail} FAILED "
          f"of {len(records)} cells ==")
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
