"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str):
    """``--mesh DATAxMODEL`` (e.g. ``2x4``) → a local (data, model) mesh.

    Device count must satisfy data*model; on a CPU host force fake devices
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before*
    the first jax call (see docs/serving.md).
    """
    try:
        data, model = (int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(
            f"--mesh expects DATAxMODEL (e.g. 2x4), got {spec!r}") from None
    have = jax.device_count()
    if data * model > have:
        raise ValueError(
            f"--mesh {spec} needs {data * model} devices but only {have} "
            f"are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model}")
    return make_local_mesh(data=data, model=model)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes: ('pod','data') multi-pod, ('data',) single."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def degraded_mesh(mesh, *, drop_data: int = 1):
    """Elastic-rescale helper: rebuild the mesh with fewer data rows
    (simulates losing a slice and re-lowering on the survivors)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    sizes["data"] = sizes["data"] - drop_data
    n_needed = 1
    for v in sizes.values():
        n_needed *= v
    devs = mesh.devices.reshape(-1)[:n_needed]
    return jax.sharding.Mesh(
        devs.reshape(tuple(sizes.values())), tuple(sizes.keys()))
