"""Serving driver: W4A16-quantized prefill + batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --batch 4 --prompt-len 32 --gen 16 --strategy fused

This is the paper's deployment scenario: weights quantized to INT4 at load
time, decode GEMMs run K≫N with small M — the Split-K regime.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import cache_len_for, ShapeSpec
from repro.core import quant
from repro.kernels import planning
from repro.models import transformer as T
from repro.runtime import steps as rsteps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--strategy", default="auto",
                    choices=["auto"] + list(planning.available_strategies()))
    ap.add_argument("--format", default=None,
                    help="quantization format name (see repro.core.quant."
                         "available_formats(): w4a16_g128 | w8a16_channel "
                         "| w4a8_g128 | any registered format); default: "
                         "the config's quant_format")
    ap.add_argument("--plan-cache", default=None,
                    help="plan-cache JSON: loaded before serving if present, "
                         "saved (with any new decisions) afterwards")
    ap.add_argument("--refine-plans", action="store_true",
                    help="run the planner's tile-search refinement pass")
    ap.add_argument("--no-quant", action="store_true")
    args = ap.parse_args(argv)

    if args.plan_cache and os.path.exists(args.plan_cache):
        n = planning.load_plan_cache(args.plan_cache, tolerant=True)
        if n >= 0:
            print(f"[serve] plan cache: loaded {n} plans "
                  f"from {args.plan_cache}")
        else:
            print(f"[serve] plan cache {args.plan_cache} unreadable; "
                  f"replanning from scratch")

    cfg = (configs.get_reduced if args.reduced else configs.get_config)(
        args.arch)
    fmt = quant.get_format(args.format or cfg.quant_format)
    cfg = dataclasses.replace(cfg, w4a16_strategy=args.strategy,
                              quant_format=fmt.name)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    if not args.no_quant:
        params = T.quantize_params(params, cfg, min_size=0)
        qbytes = sum(
            x.nbytes_packed() if hasattr(x, "nbytes_packed") else x.nbytes
            for x in jax.tree.leaves(
                params, is_leaf=lambda t: hasattr(t, "nbytes_packed")))
        print(f"[serve] {cfg.name} {fmt.name} ({args.strategy}); "
              f"weights {qbytes/1e6:.1f} MB on disk")
        if args.strategy == "auto":
            # pre-plan the decode-regime (M=batch) GEMMs: the planner's
            # decisions land in the plan cache before the first trace
            plans = planning.plan_for_params(params, M=args.batch,
                                             refine=args.refine_plans)
            for lk, plan in sorted(plans.items()):
                print(f"[serve]   plan {lk}: {plan.strategy} "
                      f"split_k={plan.split_k} "
                      f"tiles=({plan.block_m},{plan.block_n},{plan.block_k})")

    B, P, G = args.batch, args.prompt_len, args.gen
    cache_len = min(P + G, cache_len_for(
        cfg, ShapeSpec("serve", P + G, B, "decode")))
    tokens = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    extras = {}
    if cfg.vision_prefix:
        extras["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.vision_prefix, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        extras["audio_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    prefill = jax.jit(rsteps.make_prefill_step(cfg, cache_len))
    serve = jax.jit(rsteps.make_serve_step(cfg))

    t0 = time.time()
    last_logits, state = prefill(params, {"tokens": tokens, **extras})
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    pos0 = P + (cfg.vision_prefix or 0)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(G - 1):
        pos = jnp.full((B,), pos0 + i, jnp.int32)
        res = serve(params, {"state": state, "tokens": tok, "pos": pos})
        tok, state = res["next"], res["state"]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = jnp.stack(out_tokens, axis=1)
    print(f"[serve] prefill {P} toks: {t_prefill*1e3:.1f} ms; "
          f"decode {G-1} steps: {t_dec/(max(G-1,1))*1e3:.2f} ms/tok")
    print(f"[serve] sample generation (batch 0): {gen[0].tolist()}")
    if args.plan_cache:
        n = planning.save_plan_cache(args.plan_cache)
        c = planning.PLAN_CACHE
        print(f"[serve] plan cache: {n} plans -> {args.plan_cache} "
              f"({c.hits} hits / {c.misses} misses this run)")
    return gen


if __name__ == "__main__":
    main()
