"""Serving driver: W4A16-quantized continuous-batching decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --batch 4 --prompt-len 32 --gen 16 --strategy fused

    # 8 fake CPU devices, 2-way data x 4-way tensor parallel:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --mesh 2x4 --batch 4 --requests 8 --arrival-every 2

This is the paper's deployment scenario: weights quantized to INT4 at load
time, decode GEMMs run K≫N with small M — the Split-K regime. The
``runtime/engine.py`` scheduler admits/evicts requests per decode step
(continuous batching) and, on a mesh, plans every layer GEMM on its
shard-local shape (K/tp row-parallel, N/tp column-parallel).

Context lives in the paged, prefix-shared KV block pool by default
(``--ring`` restores per-slot ring caches): ``--page-size`` sets the
block granularity, ``--prefill-chunk`` interleaves long-prompt prefill
with decode, and ``--kv-format`` picks the KV block storage (``kv_fp16``
| ``kv8_channel`` per-head INT8) — validated against the registry up
front. See docs/serving.md.

``--http PORT`` swaps the in-process arrival loop for the asyncio front
door (``runtime/frontdoor.py``): real-socket clients POST /v1/generate
and stream tokens back as SSE, through a bounded admission queue
(``--queue-depth`` → 429 when full, ``--deadline-s`` → 408 once expired)
with ``GET /metrics`` live. See docs/serving.md §Front door.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import quant
from repro.kernels import planning
from repro.launch import mesh as launch_mesh
from repro.launch.presets import serve_settings_for
from repro.models import transformer as T
from repro.runtime import speculative
from repro.runtime.engine import Request, ServingEngine


def validate_kv_format(kv_format: str, weight_format: str, *,
                       paged: bool, attn_free: bool = False) -> str:
    """Resolve/validate the ``--kv-format`` × ``--format`` pair up front.

    Mirrors the planner's forced-pair refusal: a bad combination fails
    here with the registries' vocabulary instead of deep inside a trace.
    Both names must be registered, KV quantization requires the paged
    cache (the ring layout stores raw cache-dtype rows), and attention-free
    archs (rwkv) hold no KV cache for a format to apply to.
    """
    wf = quant.get_format(weight_format)          # raises w/ registry list
    kf = quant.get_kv_format(kv_format)           # raises w/ registry list
    if kf.quantized and attn_free:
        raise ValueError(
            f"--kv-format {kf.name!r} does not apply to attention-free "
            f"archs — there is no KV cache to quantize; use kv_fp16")
    if kf.quantized and not paged:
        raise ValueError(
            f"--kv-format {kf.name!r} quantizes KV blocks, which requires "
            f"the paged cache; drop --ring (or use --kv-format kv_fp16). "
            f"Registered KV formats: {quant.available_kv_formats()}")
    del wf  # every (weight, kv) registered pair is currently executable
    return kf.name


def parse_prompt_len(spec) -> "tuple[int, int]":
    """``N`` (fixed) or ``MIN:MAX`` (uniform variable length) → bounds."""
    s = str(spec)
    try:
        lo, hi = (int(x) for x in s.split(":", 1)) if ":" in s \
            else (int(s),) * 2
    except ValueError:
        raise ValueError(
            f"--prompt-len must be N or MIN:MAX, got {spec!r}") from None
    if not 0 < lo <= hi:
        raise ValueError(
            f"--prompt-len needs 0 < MIN <= MAX, got {spec!r}")
    return lo, hi


def _serve_http(engine, reqs, *, port, queue_depth, deadline_s,
                arrival_every):
    """Run the arrival simulation through the real front door: one
    real-socket HTTP client per request, tokens streamed back as SSE.
    The in-process simulation's step-count spacing maps to wall clock at
    10 ms per ``--arrival-every`` unit. Rejected requests (429/408)
    come back as ``None`` generations."""
    from repro.runtime.frontdoor import (FrontDoor, QueueSettings,
                                         sse_decode_tokens)

    async def client(port, req, delay):
        await asyncio.sleep(delay)
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        spec = {"prompt": [int(t) for t in req.prompt],
                "max_new_tokens": req.max_new_tokens,
                "priority": req.priority}
        if req.prefix_embeds is not None:
            spec["prefix_embeds"] = [[float(x) for x in row]
                                     for row in req.prefix_embeds]
        if req.audio_embeds is not None:
            spec["audio_embeds"] = [[float(x) for x in row]
                                    for row in req.audio_embeds]
        body = json.dumps(spec).encode()
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: serve\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        payload = await reader.read()
        writer.close()
        if b" 200 " not in payload.split(b"\r\n", 1)[0]:
            return None
        return sse_decode_tokens(payload)

    async def run():
        fd = FrontDoor(engine, settings=QueueSettings(
            queue_depth=queue_depth, default_deadline_s=deadline_s))
        await fd.serve(port=port)
        print(f"[serve] front door: http://{fd.host}:{fd.port} "
              f"(queue_depth {queue_depth}, deadline "
              f"{'none' if deadline_s is None else f'{deadline_s:g} s'})")
        t0 = time.time()
        got = await asyncio.gather(*(
            client(fd.port, r, i * arrival_every * 0.01)
            for i, r in enumerate(reqs)))
        report = await fd.shutdown()
        return got, report, time.time() - t0

    return asyncio.run(run())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="engine slot count (max concurrent requests)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="alias for --batch (slot-pool size)")
    ap.add_argument("--prompt-len", default="32",
                    help="prompt tokens per request: fixed N, or MIN:MAX "
                         "for uniformly-distributed variable lengths")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=None,
                    help="total simulated requests (default: the slot "
                         "count — one full static batch)")
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="request-arrival simulation: one request every K "
                         "decode steps (0 = all arrive at step 0)")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL serving mesh (e.g. 2x4); requires "
                         "data*model visible devices — on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count. Default: "
                         "single device")
    ap.add_argument("--strategy", default="auto",
                    choices=["auto"] + list(planning.available_strategies()))
    ap.add_argument("--format", default=None,
                    help="quantization format name (see repro.core.quant."
                         "available_formats(): w4a16_g128 | w8a16_channel "
                         "| w4a8_g128 | any registered format); default: "
                         "the config's quant_format")
    ap.add_argument("--ring", action="store_true",
                    help="legacy per-slot ring KV caches instead of the "
                         "paged, prefix-shared block pool (the parity "
                         "reference; see docs/serving.md)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged KV cache: tokens per physical block "
                         "(default: the arch's ServeSettings preset)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: max prompt tokens processed per "
                         "engine step, interleaved with decode — the single "
                         "prefill path for every family; 0 = the engine "
                         "default of 32 (default: the arch preset)")
    ap.add_argument("--warm-cache-mb", type=float, default=None,
                    help="warm prefix retention budget in MiB: released "
                         "page-aligned prefix chains stay adoptable and a "
                         "returning prompt skips its prefill; 0 = off "
                         "(default: the arch preset, usually 0)")
    ap.add_argument("--kv-format", default=None,
                    help="KV-cache block format (see repro.core.quant."
                         "available_kv_formats(): kv_fp16 | kv8_channel); "
                         "default: the arch preset")
    ap.add_argument("--attn-path", default=None,
                    choices=["auto", "gather", "fused"],
                    help="paged decode-attention path: gather (XLA window "
                         "reassembly) | fused (Pallas in-kernel block-table "
                         "walk) | auto (planner ranks them per backend; "
                         "default: the arch preset, usually auto)")
    ap.add_argument("--speculate", default=None,
                    help="speculative decoding proposer: off | ngram"
                         "[:max_n] | draft:layers=N (see repro.runtime."
                         "speculative.available_proposers(); default: the "
                         "arch preset, usually off)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="draft tokens scored per verify step "
                         "(default: the arch preset)")
    ap.add_argument("--plan-cache", default=None,
                    help="plan-cache JSON: loaded before serving if present, "
                         "saved (with any new decisions) afterwards")
    ap.add_argument("--refine-plans", action="store_true",
                    help="run the planner's tile-search refinement pass")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve through the async HTTP front door on PORT "
                         "(0 = ephemeral): real-socket POST /v1/generate "
                         "clients streaming SSE tokens, with GET /metrics "
                         "live; arrivals spaced --arrival-every x 10 ms")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="front-door admission queue bound before 429 "
                         "(--http only; default: the arch preset)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request SLO deadline in seconds, "
                         "408 once expired (--http only; 0 = none; "
                         "default: the arch preset)")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--verbose", action="store_true",
                    help="per-step engine log lines")
    args = ap.parse_args(argv)

    if args.plan_cache and os.path.exists(args.plan_cache):
        n = planning.load_plan_cache(args.plan_cache, tolerant=True)
        if n >= 0:
            print(f"[serve] plan cache: loaded {n} plans "
                  f"from {args.plan_cache}")
        else:
            print(f"[serve] plan cache {args.plan_cache} unreadable; "
                  f"replanning from scratch")

    cfg = (configs.get_reduced if args.reduced else configs.get_config)(
        args.arch)
    sset = serve_settings_for(args.arch)
    paged = not args.ring
    page_size = args.page_size or sset.page_size
    prefill_chunk = sset.prefill_chunk if args.prefill_chunk is None \
        else (args.prefill_chunk or None)
    warm_cache_mb = sset.warm_cache_mb if args.warm_cache_mb is None \
        else args.warm_cache_mb
    fmt = quant.get_format(args.format or cfg.quant_format)
    kv_format = validate_kv_format(args.kv_format or sset.kv_format,
                                   fmt.name, paged=paged,
                                   attn_free=cfg.attn_free)
    speculate = sset.speculate if args.speculate is None \
        else (args.speculate if args.speculate != "off" else None)
    spec_k = sset.spec_k if args.spec_k is None else args.spec_k
    # refuse bad proposer/spec-k pairs up front with the registry's
    # vocabulary (same contract as --kv-format), not mid-serving-loop
    speculative.validate_speculate(speculate, spec_k, cfg=cfg, paged=paged)
    cfg = dataclasses.replace(cfg, w4a16_strategy=args.strategy,
                              quant_format=fmt.name)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    if not args.no_quant:
        params = T.quantize_params(params, cfg, min_size=0)
        qbytes = sum(
            x.nbytes_packed() if hasattr(x, "nbytes_packed") else x.nbytes
            for x in jax.tree.leaves(
                params, is_leaf=lambda t: hasattr(t, "nbytes_packed")))
        print(f"[serve] {cfg.name} {fmt.name} ({args.strategy}); "
              f"weights {qbytes/1e6:.1f} MB on disk")

    mesh = launch_mesh.parse_mesh(args.mesh) if args.mesh else None
    if mesh is not None:
        print(f"[serve] mesh: "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"({mesh.devices.size} devices)")

    B = args.max_batch or args.batch
    pmin, pmax = parse_prompt_len(args.prompt_len)
    P, G = pmax, args.gen      # slots are sized for the longest prompt
    R = args.requests or B
    proposer = None
    if speculate is not None:
        proposer = speculative.make_proposer(speculate, target_cfg=cfg)
    attn_path = args.attn_path or sset.attn_path
    engine = ServingEngine(cfg, params, mesh=mesh, max_batch=B,
                           max_prompt_len=P, max_new_tokens=G,
                           refine_plans=args.refine_plans, paged=paged,
                           page_size=page_size, prefill_chunk=prefill_chunk,
                           warm_cache_mb=warm_cache_mb,
                           kv_format=kv_format, speculate=proposer,
                           spec_k=spec_k, attn_path=attn_path)
    print(f"[serve] engine: {B} slots, cache_len {engine.cache_len} "
          f"(prompt {P} + prefix {cfg.vision_prefix or 0} + gen {G})")
    if proposer is not None:
        print(f"[serve] speculative: proposer {proposer.name!r}, "
              f"k={spec_k} (verify scores {B}x{spec_k + 1} positions/step)")
    if engine.paged:
        print(f"[serve] paged KV: {engine.num_pages} blocks x "
              f"{engine.page_size} tokens ({engine.pages_slot}/slot), "
              f"kv_format {engine.kv_format}, prefill_chunk "
              f"{engine.prefill_chunk}"
              + (f", warm cache {warm_cache_mb:g} MiB"
                 if engine.alloc is not None and engine.alloc.warm_bytes
                 else ""))
        print(f"[serve] attn path: {engine.attn_path}"
              + (f" (kv_partitions={engine.kv_partitions})"
                 if engine.attn_path == "fused" else "")
              + ("" if args.attn_path else " [planned]"))
    for lk, plan in sorted(engine.plans.items()):
        print(f"[serve]   plan {lk}: {plan.strategy} "
              f"split_k={plan.split_k} "
              f"tiles=({plan.block_m},{plan.block_n},{plan.block_k})")

    # request-arrival simulation: R requests over the same random prompt
    # distribution, one every --arrival-every decode steps
    tokens = jax.random.randint(key, (R, P), 0, cfg.vocab_size)
    plens = [P] * R if pmin == pmax else [
        int(x) for x in jax.random.randint(
            jax.random.fold_in(key, 7), (R,), pmin, pmax + 1)]
    reqs = []
    for i in range(R):
        extras = {}
        if cfg.vision_prefix:
            extras["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(key, i),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            extras["audio_embeds"] = jax.random.normal(
                jax.random.fold_in(key, i),
                (cfg.encoder_seq, cfg.d_model), cfg.dtype)
        reqs.append(Request(rid=i, prompt=tokens[i][:plens[i]],
                            max_new_tokens=G,
                            arrival_step=i * args.arrival_every, **extras))
    if pmin != pmax:
        print(f"[serve] prompts: variable length {pmin}:{pmax} "
              f"(mean {sum(plens) / R:.1f})")

    if args.http is not None:
        got, report, wall = _serve_http(
            engine, reqs, port=args.http,
            queue_depth=args.queue_depth or sset.queue_depth,
            deadline_s=sset.deadline_s if args.deadline_s is None
            else (args.deadline_s or None),
            arrival_every=args.arrival_every)
    else:
        t0 = time.time()
        report = engine.run(reqs, verbose=args.verbose)
        wall = time.time() - t0
        got = [report.results[r.rid] for r in reqs]

    ls = report.latency_stats()
    print(f"[serve] {R} requests in {report.steps} steps / {wall:.2f} s "
          f"wall; prefill {report.prefill_s*1e3:.1f} ms total")
    print(f"[serve] decode: {report.decode_tokens} tokens in "
          f"{report.decode_s:.3f} s = {report.tokens_per_s:.1f} tok/s "
          f"({report.decode_s / max(len(report.step_records), 1) * 1e3:.2f} "
          f"ms/step); latency p50 {ls['p50']*1e3:.1f} / "
          f"p95 {ls['p95']*1e3:.1f} / p99 {ls['p99']*1e3:.1f} ms "
          f"max {ls['max']*1e3:.1f} ms")
    if args.http is not None:
        done = sum(1 for g in got if g is not None)
        ts = report.ttft_stats()
        print(f"[serve] front door: {done}/{R} served, "
              f"{report.rejected_429} x 429, {report.rejected_408} x 408; "
              f"peak queue {report.peak_queue_depth}; "
              f"ttft p50 {ts['p50']*1e3:.1f} ms p99 {ts['p99']*1e3:.1f} ms")
    if engine.paged:
        worst = engine.pages_slot * min(B, R)
        print(f"[serve] pages: peak {report.peak_pages} in use "
              f"(worst-case {worst} without sharing)")
    if proposer is not None:
        print(f"[serve] speculative: {report.accepted_tokens}/"
              f"{report.proposed_tokens} drafts accepted "
              f"({report.acceptance_rate:.0%}); tok/s above counts "
              f"accepted tokens only")
    print(f"[serve] sample generation (request 0): {got[0]}")
    if args.plan_cache:
        n = planning.save_plan_cache(args.plan_cache)
        c = planning.PLAN_CACHE
        print(f"[serve] plan cache: {n} plans -> {args.plan_cache} "
              f"({c.hits} hits / {c.misses} misses this run)")
    return jnp.asarray([g for g in got if g is not None], jnp.int32)


if __name__ == "__main__":
    main()
