"""Distributed checkpoint save/restore (npz-based, atomic, resume-safe).

Production notes (1000+ node deployment):
  * every leaf is written under its pytree key-path, so restore is
    structure-checked — a changed model config fails loudly, not silently;
  * writes go to ``<dir>/tmp.<step>`` and are atomically renamed to
    ``step_<n>`` — a host dying mid-save never corrupts the latest
    checkpoint (the restart picks the previous complete step);
  * per-host sharded saving: each host writes only the addressable shards
    of its jax.Arrays (here: single host writes everything);
  * QuantizedTensor leaves round-trip with their aux (group size, dtype).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import QuantizedTensor

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))


def _key_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
        for k in path)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomically persist a pytree (params/opt state/etc.) for ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    arrays = {}
    meta = {"step": step, "quantized": {}, "dtypes": {}, "extra": extra or {}}

    def put(key, arr):
        arr = np.asarray(arr)
        if arr.dtype == jnp.bfloat16:       # npz has no bf16 — store raw bits
            meta["dtypes"][key] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[key] = arr

    for path, leaf in leaves:
        key = _key_str(path)
        if isinstance(leaf, QuantizedTensor):
            put(key + "/__packed", leaf.packed)
            put(key + "/__scales", leaf.scales)
            if leaf.zeros is not None:
                put(key + "/__zeros", leaf.zeros)
            meta["quantized"][key] = {
                "group_size": leaf.group_size,
                "out_dtype": jnp.dtype(leaf.out_dtype).name,
            }
        else:
            put(key, leaf)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for n in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(n))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None):
    """Restore into the structure of ``like`` (shape/dtype-checked).

    Returns (tree, step, extra) or (None, None, None) when no checkpoint.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None, None
    d = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    def get(key):
        arr = data[key]
        if meta.get("dtypes", {}).get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        return arr

    leaves, treedef = _flatten(like)
    out = []
    for path, leaf in leaves:
        key = _key_str(path)
        if isinstance(leaf, QuantizedTensor):
            q = meta["quantized"][key]
            zeros_key = key + "/__zeros"
            out.append(QuantizedTensor(
                packed=jnp.asarray(get(key + "/__packed")),
                scales=jnp.asarray(get(key + "/__scales")),
                zeros=(jnp.asarray(get(zeros_key))
                       if zeros_key in data else None),
                group_size=q["group_size"],
                out_dtype=jnp.dtype(q["out_dtype"]),
            ))
        else:
            arr = get(key)
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint mismatch at {key}: {arr.shape} != {want}")
            out.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), step, meta["extra"]
