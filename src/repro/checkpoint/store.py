"""Distributed checkpoint save/restore (npz-based, atomic, resume-safe).

Production notes (1000+ node deployment):
  * every leaf is written under its pytree key-path, so restore is
    structure-checked — a changed model config fails loudly, not silently;
  * writes go to ``<dir>/tmp.<step>`` and are atomically renamed to
    ``step_<n>`` — a host dying mid-save never corrupts the latest
    checkpoint (the restart picks the previous complete step);
  * per-host sharded saving: each host writes only the addressable shards
    of its jax.Arrays (here: single host writes everything);
  * QuantizedTensor leaves round-trip with a full QuantFormat metadata
    sidecar (format descriptor + group size + dtype) — restoring into a
    model that expects a *different* quantization format fails loudly with
    a format-mismatch error instead of silently mis-decoding the payload.
    Pre-format checkpoints (no "format" key) resolve through the
    default-format shim.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import (
    QuantFormat,
    QuantizedTensor,
    w4a16_format_for,
)

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))


def _key_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
        for k in path)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomically persist a pytree (params/opt state/etc.) for ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, _ = _flatten(tree)
    arrays = {}
    meta = {"step": step, "quantized": {}, "dtypes": {}, "extra": extra or {}}

    def put(key, arr):
        arr = np.asarray(arr)
        if arr.dtype == jnp.bfloat16:       # npz has no bf16 — store raw bits
            meta["dtypes"][key] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[key] = arr

    for path, leaf in leaves:
        key = _key_str(path)
        if isinstance(leaf, QuantizedTensor):
            put(key + "/__packed", leaf.packed)
            put(key + "/__scales", leaf.scales)
            if leaf.zeros is not None:
                put(key + "/__zeros", leaf.zeros)
            meta["quantized"][key] = {
                "group_size": leaf.group_size,
                "out_dtype": jnp.dtype(leaf.out_dtype).name,
                "format": leaf.format.to_dict(),
            }
        else:
            put(key, leaf)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for n in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(n))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None):
    """Restore into the structure of ``like`` (shape/dtype-checked).

    Returns (tree, step, extra) or (None, None, None) when no checkpoint.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None, None
    d = os.path.join(ckpt_dir, f"step_{step}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)

    def get(key):
        arr = data[key]
        if meta.get("dtypes", {}).get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        return arr

    leaves, treedef = _flatten(like)
    out = []
    for path, leaf in leaves:
        key = _key_str(path)
        if isinstance(leaf, QuantizedTensor):
            q = meta["quantized"].get(key)
            if q is None:
                raise ValueError(
                    f"checkpoint mismatch at {key}: the model expects a "
                    f"quantized ({leaf.format.name}) leaf but the "
                    f"checkpoint stores a dense array — quantize the "
                    f"restored tree (layers.quantize_tree) instead of "
                    f"restoring into a quantized template")
            # pre-format checkpoints carry only group_size: resolve them
            # through the default-format (W4A16-family) shim. Deserialize
            # by value (no registry mutation): restore must not register
            # foreign formats, and a name collision with different fields
            # should surface as the mismatch error below, not a
            # registration conflict.
            fmt = QuantFormat.from_dict(q["format"]) if "format" in q else \
                w4a16_format_for(q["group_size"],
                                 symmetric=key + "/__zeros" not in data)
            if fmt != leaf.format:
                detail = "" if fmt.name != leaf.format.name else (
                    f" (same name, different fields: {fmt.to_dict()} vs "
                    f"{leaf.format.to_dict()})")
                raise ValueError(
                    f"checkpoint format mismatch at {key}: checkpoint was "
                    f"saved as {fmt.name!r} but the model expects "
                    f"{leaf.format.name!r}{detail}; re-quantize the source "
                    f"checkpoint or restore with a config whose "
                    f"quant_format is {fmt.name!r}")
            want = tuple(getattr(leaf.packed, "shape", ()))
            got = tuple(data[key + "/__packed"].shape)
            if want and got != want:
                raise ValueError(
                    f"checkpoint mismatch at {key}: packed payload "
                    f"{got} != {want}")
            zeros_key = key + "/__zeros"
            out.append(QuantizedTensor(
                packed=jnp.asarray(get(key + "/__packed")),
                scales=jnp.asarray(get(key + "/__scales")),
                zeros=(jnp.asarray(get(zeros_key))
                       if zeros_key in data else None),
                group_size=q["group_size"],
                out_dtype=jnp.dtype(q["out_dtype"]),
                format=fmt,
            ))
        else:
            if key not in data and key + "/__packed" in data:
                fmt = meta["quantized"].get(key, {}).get(
                    "format", {}).get("name", "a quantized format")
                raise ValueError(
                    f"checkpoint mismatch at {key}: the checkpoint stores "
                    f"a quantized ({fmt}) leaf but the model expects a "
                    f"dense array — restore into a quantized template "
                    f"(quantize_tree the `like` tree first)")
            arr = get(key)
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint mismatch at {key}: {arr.shape} != {want}")
            out.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), step, meta["extra"]
