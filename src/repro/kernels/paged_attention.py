"""Fused paged-attention kernel: block-table walk + KV dequant + online
softmax in ONE pass over the KV working set — for decode (q_len=1),
chunked prefill (q_len=C) and speculative verify (q_len=k+1).

The paper's profiling says bandwidth-bound decode loses to *extra
global-memory traffic*, not compute — and the XLA gather path is exactly
that: ``kvcache.gather_window`` materializes each slot's whole (dequantized)
KV window to HBM, then attention reads it back. PR 9 made chunked prefill
the single prefill path, so every admit and every speculative verify paid
that round-trip too. This kernel walks the per-slot block tables *inside*
the kernel instead, for any query length:

  grid ``(B·Hkv, Q_tiles, S, P)`` — one (slot, kv-head) pair per row of the
  first axis; ``Q_tiles`` tiles the chunk's queries so each kernel instance
  holds ``Tq·G ≤ 128`` query rows (``planning.choose_q_block`` — decode's
  q_len=1 degenerates to the old flash-decoding grid); the slot's
  ``T = S·P`` table entries are split into ``S`` Split-K style partitions
  of ``P`` physical pages each (``planning.choose_kv_partitions``, now
  occupancy-aware of the Q-tile axis — the paper's K ≫ N fix applied to
  the KV axis).

  block tables ride scalar prefetch (``pltpu.PrefetchScalarGridSpec``), so
  the K/V BlockSpec index maps resolve ``tables[slot, s·P + p]`` to a
  *physical page* and the pages stream through VMEM double-buffering — the
  gathered window never exists in HBM. Per-query positions and the chunk
  ``start`` arrive as small expanded int32 operands so the kernel reads
  only its own block.

  a :class:`~repro.kernels.template.DensePages` /
  :class:`~repro.kernels.template.Int8ChannelPages` KV stage produces the
  in-VMEM (page_size, D) tiles (identity load or per-(token, head) INT8
  dequant matching ``kv_dequantize`` exactly), and the flash online softmax
  runs per partition with ``(m, l, acc)`` scratch over all Tq·G rows.

  each partition flushes unnormalized ``(acc, m, l)`` partials; a small
  host-side combine epilogue merges partitions (``exp(m_s - m_max)``
  rescale) and normalizes — the Split-K phase-3 reduce of Alg. 1, at
  O(B·q_len·Hq·S·D) fp32 bytes instead of a second trip over the window.

Masking is purely positional via the pool's ``page_pos`` tags (``-1`` =
empty — the null block a ``-1`` table entry resolves to is all ``-1``
tags) plus the per-row causal / sliding-window / chunk-start clauses, so
ring-wrap SWA, vision-prefix, shared-prefix and stale-rejected-draft
semantics carry over from the gather path verbatim: pool entries at
positions ≥ the chunk start (a sharing peer's copy of this chunk, or a
rejected draft's leftover tags) are masked in-kernel, the single-counting
rule the gather path applied by rewriting ``win.pos``. The chunk's own
K/V — which the caller scatters only *after* attention, preserving the
gather-before-scatter SWA-wrap ordering — contributes one extra
"partition" computed as a tiny C×C host einsum and merged in the same
combine epilogue. Token parity with gather + ``prefix_chunk_attention``
is asserted by tests/test_paged_attention.py.

``interpret=None`` auto-selects interpret mode on CPU hosts
(``common.resolve_interpret``) so the parity suite runs on CPU CI, same as
the GEMM template kernels; the planner (``planning.plan_attention``) never
*auto*-chooses this path off-TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import KVFormat
from repro.kernels import common, template

NEG_INF = -1e30
LANES = 128

__all__ = ["fused_paged_attention", "fused_chunk_attention", "kv_stage_for"]


def kv_stage_for(pool, fmt: KVFormat):
    """Build the KV stage for a pool/format pair (the attention analogue of
    picking a WeightStage per QuantFormat)."""
    if not fmt.quantized:
        return template.DensePages(k_pool=pool.k_pool, v_pool=pool.v_pool)
    if pool.k_scale is None or pool.v_scale is None:
        raise ValueError(
            f"KV format {fmt.name!r} stores per-(token, head) scales, but "
            f"the pool carries none — was it built with init_pool(..., "
            f"kv_format={fmt.name!r})?")
    return template.Int8ChannelPages(
        k_pool=pool.k_pool, v_pool=pool.v_pool,
        k_scale=pool.k_scale, v_scale=pool.v_scale)


def _make_kernel(stage, *, P: int, window: int, n_stage: int,
                 compute_dtype):
    def kernel(tbl_ref, q_ref, qpos_ref, spos_ref, *rest):
        # tbl_ref (B, S*P) is the scalar-prefetch operand driving the
        # BlockSpec index maps below; qpos/spos are per-row int32 blocks.
        stage_refs = rest[:n_stage]
        pp_ref, o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = rest[n_stage:]
        p = pl.program_id(3)

        @pl.when(p == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0, 0]                                # (QG, D)
        k, v = stage.produce(stage_refs, compute_dtype)   # (ps, D) each
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (QG, ps)

        # pos-tag masking — identical to prefix_chunk_attention's
        # ``kpos >= 0 & kpos <= qpos`` (+ window), plus ``kpos < start``:
        # the pool copy of anything at/after the chunk start (a peer's
        # duplicate, a rejected draft's stale tags) is masked so only the
        # in-flight segment supplies those positions. The null block's
        # tags are all -1, so unmapped table entries mask themselves out.
        kpos = pp_ref[0][None, :]                         # (1, ps)
        qe = qpos_ref[0, 0][:, None]                      # (QG, 1)
        se = spos_ref[0, 0][:, None]
        valid = (kpos >= 0) & (kpos <= qe) & (kpos < se)
        if window:
            valid &= kpos > qe - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[:, :1]                             # (QG, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)                         # (QG, ps)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * corr + jnp.sum(pexp, axis=-1, keepdims=True),
            l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pexp.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

        @pl.when(p == P - 1)
        def _flush():
            o_ref[0, 0, 0, 0] = acc_ref[...]              # unnormalized
            mo_ref[0, 0, 0, 0] = m_ref[...]
            lo_ref[0, 0, 0, 0] = l_ref[...]

    return kernel


def _pooled_partials(qg, positions, start, pool, tables, *, window: int,
                     fmt: KVFormat, kv_partitions, interpret):
    """Kernel pass over the pooled pages; per-query unnormalized partials.

    qg: (B, C, Hkv, G, D) pre-scaled queries in the compute dtype;
    positions: (B, C) int32 (-1 = padded row); start: (B,) first chunk
    position per slot (pool entries at ``kpos >= start`` are masked).
    Returns (acc (B,Hkv,C,S,G,D), m (B,Hkv,C,S,G), l (B,Hkv,C,S,G)) with
    ``S`` the Split-K partition count over the page axis.
    """
    B, C, Hkv, G, D = qg.shape
    ps = pool.page_size
    T = tables.shape[1]
    from repro.kernels import planning  # lazy: keep module load light

    Tq = planning.choose_q_block(C, G)
    QT = C // Tq
    QG = Tq * G
    if kv_partitions is None:
        kv_partitions = planning.choose_kv_partitions(B, Hkv, T, q_tiles=QT)
    S = max(1, min(int(kv_partitions), T))
    if T % S:
        raise ValueError(
            f"kv_partitions={S} must divide the table length T={T} "
            f"(choose_kv_partitions only returns divisors)")
    P = T // S

    # host-side prep: q rows laid out (qt, tq, g); per-row positions and
    # chunk starts expanded on the host so each kernel instance reads
    # nothing but its own (1, 1, QG) block
    qk = qg.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, QT, QG, D)
    qpos = jnp.broadcast_to(
        positions.reshape(B, QT, Tq, 1).astype(jnp.int32),
        (B, QT, Tq, G)).reshape(B, QT, QG)
    spos = jnp.broadcast_to(
        start.reshape(B, 1, 1).astype(jnp.int32), (B, QT, QG))
    bt = jnp.where(tables < 0, 0, tables).astype(jnp.int32)   # NULL_BLOCK=0

    stage = kv_stage_for(pool, fmt)
    operands = stage.operands()
    n_stage = len(operands)

    def slot(bh):
        return bh // Hkv

    def head(bh):
        return bh % Hkv

    def page(bh, s, p, tbl):
        return tbl[slot(bh), s * P + p]

    in_specs = [
        pl.BlockSpec((1, 1, 1, QG, D),
                     lambda bh, qt, s, p, tbl:
                     (slot(bh), head(bh), qt, 0, 0)),
        pl.BlockSpec((1, 1, QG),
                     lambda bh, qt, s, p, tbl: (slot(bh), qt, 0)),
        pl.BlockSpec((1, 1, QG),
                     lambda bh, qt, s, p, tbl: (slot(bh), qt, 0)),
    ]
    for shape in stage.block_shapes(ps, D):
        if len(shape) == 4:           # payload pool (nb, ps, Hkv, D)
            in_specs.append(pl.BlockSpec(
                shape, lambda bh, qt, s, p, tbl:
                (page(bh, s, p, tbl), 0, head(bh), 0)))
        else:                         # scale pool (nb, ps, Hkv)
            in_specs.append(pl.BlockSpec(
                shape, lambda bh, qt, s, p, tbl:
                (page(bh, s, p, tbl), 0, head(bh))))
    in_specs.append(pl.BlockSpec(                  # page_pos tags (nb, ps)
        (1, ps), lambda bh, qt, s, p, tbl: (page(bh, s, p, tbl), 0)))

    def part_spec(last):
        return pl.BlockSpec((1, 1, 1, 1, QG, last),
                            lambda bh, qt, s, p, tbl:
                            (slot(bh), head(bh), qt, s, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, QT, S, P),
        in_specs=in_specs,
        out_specs=[part_spec(D), part_spec(LANES), part_spec(LANES)],
        scratch_shapes=[
            pltpu.VMEM((QG, LANES), jnp.float32),     # running max
            pltpu.VMEM((QG, LANES), jnp.float32),     # running denom
            pltpu.VMEM((QG, D), jnp.float32),         # unnormalized acc
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        _make_kernel(stage, P=P, window=window, n_stage=n_stage,
                     compute_dtype=qk.dtype),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, QT, S, QG, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, QT, S, QG, LANES), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, QT, S, QG, LANES), jnp.float32),
        ],
        compiler_params=common.compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, qk, qpos, spos, *operands, pool.page_pos)

    def per_query(x):
        # (B, Hkv, QT, S, QG, ·) → (B, Hkv, C, S, G, ·): split QG = Tq·G
        # and move the query axis out of the partition axis's way
        y = x.reshape(B, Hkv, QT, S, Tq, G, *x.shape[5:])
        y = jnp.moveaxis(y, 4, 3)
        return y.reshape(B, Hkv, C, S, G, *x.shape[5:])

    return (per_query(o_part), per_query(m_part[..., 0]),
            per_query(l_part[..., 0]))


def _combine(acc, m, l):
    """Merge partition partials over axis 3 and normalize — the Split-K
    phase-3 reduce of Alg. 1. Fully-masked partitions carry m = NEG_INF
    and cancel via exp(NEG_INF - m_max) = 0; fully-masked rows (padded
    queries) come out finite garbage that callers discard."""
    m_max = jnp.max(m, axis=3)                         # (B, Hkv, C, G)
    alpha = jnp.exp(m - m_max[:, :, :, None])          # (B, Hkv, C, S, G)
    l_tot = jnp.sum(l * alpha, axis=3)
    out = jnp.sum(acc * alpha[..., None], axis=3)      # (B, Hkv, C, G, D)
    return out / jnp.maximum(l_tot, 1e-30)[..., None]


def fused_paged_attention(
    q: jax.Array,                 # (B, Hq, D) — one new token per slot
    pool,                         # kvcache.PagedKVCache (one layer)
    tables: jax.Array,            # (B, T) int32 block tables, -1 = unmapped
    pos: jax.Array,               # (B,) int32 absolute positions
    *,
    window: int = 0,
    fmt: KVFormat,
    out_dtype,
    kv_partitions: Optional[int] = None,
    interpret=None,
) -> jax.Array:
    """One-pass paged decode attention; drop-in for ``gather_window`` +
    ``decode_attention`` (same masking, same dtype policy, same output).

    The q_len=1 regime of the multi-query kernel: decode inserts the new
    token BEFORE attending, so its position is already in the pool and
    ``start = pos + 1`` makes the chunk-start clause ``kpos < start``
    collapse onto the decode mask ``kpos <= pos`` exactly.

    ``kv_partitions`` is the Split-K degree over the page axis (None →
    ``planning.choose_kv_partitions``); ``interpret=None`` auto-selects
    interpret mode on CPU.
    """
    interpret = common.resolve_interpret(interpret)
    B, Hq, D = q.shape
    Hkv = pool.k_pool.shape[2]
    G = Hq // Hkv
    # host-side prep, mirroring the gather path's dtype policy exactly:
    # q pre-scaled in fp32 then cast to the cache compute dtype
    compute_dtype = jnp.dtype(out_dtype)
    qg = (q.reshape(B, 1, Hkv, G, D).astype(jnp.float32)
          * (D ** -0.5)).astype(compute_dtype)
    pos = pos.astype(jnp.int32)
    acc, m, l = _pooled_partials(
        qg, pos[:, None], pos + 1, pool, tables, window=window, fmt=fmt,
        kv_partitions=kv_partitions, interpret=interpret)
    out = _combine(acc, m, l)                          # (B, Hkv, 1, G, D)
    return out[:, :, 0].reshape(B, Hq, D).astype(q.dtype)


def fused_chunk_attention(
    q: jax.Array,                 # (B, C, Hq, D) rope'd chunk queries
    kseg: jax.Array,              # (B, C, Hkv, D) chunk K after the
    vseg: jax.Array,              # (B, C, Hkv, D) quantize round-trip
    pool,                         # kvcache.PagedKVCache (one layer)
    tables: jax.Array,            # (B, T) int32 block tables, -1 = unmapped
    positions: jax.Array,         # (B, C) int32 absolute, -1 = padding
    *,
    window: int = 0,
    fmt: KVFormat,
    out_dtype,
    kv_partitions: Optional[int] = None,
    interpret=None,
) -> jax.Array:
    """One-pass paged attention for a (B, C) chunk — chunked prefill
    (C = prefill chunk) and speculative verify (C = k+1): drop-in for
    ``gather_window`` + segment concat + ``prefix_chunk_attention``.

    The pooled window is one kernel pass (entries at positions ≥ the
    chunk start are masked in-kernel — the single-counting rule the
    gather path applied via ``wpos``); the C×C intra-chunk attention over
    ``kseg``/``vseg`` — the chunk's own K/V after the same
    quantize→dequantize round-trip its stored copy takes — is a tiny host
    einsum merged into the combine epilogue as one extra partition.
    Callers scatter the chunk into the pool only AFTER this returns,
    preserving the gather-before-scatter ordering that keeps SWA ring
    wrap correct. Rows with ``positions < 0`` produce garbage the callers
    discard, exactly like the gather path.
    """
    interpret = common.resolve_interpret(interpret)
    B, C, Hq, D = q.shape
    Hkv = kseg.shape[2]
    G = Hq // Hkv
    compute_dtype = jnp.dtype(out_dtype)
    qg = (q.reshape(B, C, Hkv, G, D).astype(jnp.float32)
          * (D ** -0.5)).astype(compute_dtype)
    positions = positions.astype(jnp.int32)
    acc, m, l = _pooled_partials(
        qg, positions, positions[:, 0], pool, tables, window=window,
        fmt=fmt, kv_partitions=kv_partitions, interpret=interpret)

    # intra-chunk partial: prefix_chunk_attention's mask and dtype policy
    # over the segment alone (fp32 scores, p cast to the V dtype)
    ks = kseg.astype(compute_dtype)
    vs = vseg.astype(compute_dtype)
    s = jnp.einsum("bchgd,bwhd->bhcgw", qg, ks,
                   preferred_element_type=jnp.float32)  # (B,Hkv,C,G,C)
    kpos = positions[:, None, None, None, :]
    qpos = positions[:, None, :, None, None]
    valid = (kpos >= 0) & (kpos <= qpos)
    if window:
        valid = valid & (kpos > qpos - window)
    s = jnp.where(valid, s, NEG_INF)
    m_seg = jnp.max(s, axis=-1)                         # (B, Hkv, C, G)
    pexp = jnp.exp(s - m_seg[..., None])
    l_seg = jnp.sum(pexp, axis=-1)
    acc_seg = jnp.einsum("bhcgw,bwhd->bhcgd", pexp.astype(vs.dtype), vs,
                         preferred_element_type=jnp.float32)

    acc = jnp.concatenate([acc, acc_seg[:, :, :, None]], axis=3)
    m = jnp.concatenate([m, m_seg[:, :, :, None]], axis=3)
    l = jnp.concatenate([l, l_seg[:, :, :, None]], axis=3)
    out = _combine(acc, m, l)                           # (B, Hkv, C, G, D)
    out = out.transpose(0, 2, 1, 3, 4).reshape(B, C, Hq, D)
    return out.astype(q.dtype)
