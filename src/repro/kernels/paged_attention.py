"""Fused paged-attention decode kernel: block-table walk + KV dequant +
online softmax in ONE pass over the KV working set.

The paper's profiling says bandwidth-bound decode loses to *extra
global-memory traffic*, not compute — and the XLA gather path is exactly
that: ``kvcache.gather_window`` materializes each slot's whole (dequantized)
KV window to HBM, then ``attention.decode_attention`` reads it back. This
kernel walks the per-slot block tables *inside* the kernel instead:

  grid ``(B·Hkv, S, P)`` — one (slot, kv-head) pair per row of the first
  axis; the slot's ``T = S·P`` table entries are split into ``S`` Split-K
  style partitions of ``P`` physical pages each (``planning.
  choose_kv_partitions`` — the paper's K ≫ N occupancy fix, applied to the
  KV axis: decode runs at B·Hkv tiles, which underfills the chip exactly
  like the paper's Fig. 2 shapes).

  block tables + positions ride scalar prefetch
  (``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps
  resolve ``tables[slot, s·P + p]`` to a *physical page* and the pages
  stream through VMEM double-buffering — the gather never exists in HBM.

  a :class:`~repro.kernels.template.DensePages` /
  :class:`~repro.kernels.template.Int8ChannelPages` KV stage produces the
  in-VMEM (page_size, D) tiles (identity load or per-(token, head) INT8
  dequant matching ``kv_dequantize`` exactly), and the flash-decoding
  online softmax runs per partition with ``(m, l, acc)`` in VMEM scratch.

  each partition flushes unnormalized ``(acc, m, l)`` partials; a small
  host-side combine epilogue merges partitions (``exp(m_s - m_max)``
  rescale) and normalizes — the Split-K phase-3 reduce of Alg. 1, at
  O(B·Hq·S·D) fp32 bytes instead of a second trip over the window.

Masking is purely positional via the pool's ``page_pos`` tags (``-1`` =
empty — the null block a ``-1`` table entry resolves to is all ``-1`` tags),
so ring-wrap SWA and vision-prefix semantics carry over from the gather
path verbatim. Token parity with gather + ``decode_attention`` is asserted
by tests/test_paged_attention.py.

``interpret=None`` auto-selects interpret mode on CPU hosts
(``common.resolve_interpret``) so the parity suite runs on CPU CI, same as
the GEMM template kernels; the planner (``planning.plan_attention``) never
*auto*-chooses this path off-TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import KVFormat
from repro.kernels import common, template

NEG_INF = -1e30
LANES = 128

__all__ = ["fused_paged_attention", "kv_stage_for"]


def kv_stage_for(pool, fmt: KVFormat):
    """Build the KV stage for a pool/format pair (the attention analogue of
    picking a WeightStage per QuantFormat)."""
    if not fmt.quantized:
        return template.DensePages(k_pool=pool.k_pool, v_pool=pool.v_pool)
    if pool.k_scale is None or pool.v_scale is None:
        raise ValueError(
            f"KV format {fmt.name!r} stores per-(token, head) scales, but "
            f"the pool carries none — was it built with init_pool(..., "
            f"kv_format={fmt.name!r})?")
    return template.Int8ChannelPages(
        k_pool=pool.k_pool, v_pool=pool.v_pool,
        k_scale=pool.k_scale, v_scale=pool.v_scale)


def _make_kernel(stage, *, Hkv: int, P: int, window: int, n_stage: int,
                 compute_dtype):
    def kernel(tbl_ref, pos_ref, q_ref, *rest):
        # tbl_ref (B, S*P) / pos_ref (B,) are the scalar-prefetch operands;
        # the same refs drive the BlockSpec index maps below.
        stage_refs = rest[:n_stage]
        pp_ref, o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = rest[n_stage:]
        bh = pl.program_id(0)
        p = pl.program_id(2)

        @pl.when(p == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0]                                   # (G, D)
        k, v = stage.produce(stage_refs, compute_dtype)   # (ps, D) each
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, ps)

        # pos-tag masking — identical to prefix_chunk_attention's
        # ``kpos >= 0 & kpos <= qpos`` (+ window); the null block's tags
        # are all -1, so unmapped table entries mask themselves out
        kpos = pp_ref[0]                                  # (ps,) int32
        qpos = pos_ref[bh // Hkv]
        valid = (kpos >= 0) & (kpos <= qpos)
        if window:
            valid &= kpos > qpos - window
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_ref[:, :1]                             # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)                         # (G, ps)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * corr + jnp.sum(pexp, axis=-1, keepdims=True),
            l_ref.shape)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pexp.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

        @pl.when(p == P - 1)
        def _flush():
            o_ref[0, 0, 0] = acc_ref[...]                 # unnormalized
            mo_ref[0, 0, 0] = m_ref[...]
            lo_ref[0, 0, 0] = l_ref[...]

    return kernel


def fused_paged_attention(
    q: jax.Array,                 # (B, Hq, D) — one new token per slot
    pool,                         # kvcache.PagedKVCache (one layer)
    tables: jax.Array,            # (B, T) int32 block tables, -1 = unmapped
    pos: jax.Array,               # (B,) int32 absolute positions
    *,
    window: int = 0,
    fmt: KVFormat,
    out_dtype,
    kv_partitions: Optional[int] = None,
    interpret=None,
) -> jax.Array:
    """One-pass paged decode attention; drop-in for ``gather_window`` +
    ``decode_attention`` (same masking, same dtype policy, same output).

    ``kv_partitions`` is the Split-K degree over the page axis (None →
    ``planning.choose_kv_partitions``); ``interpret=None`` auto-selects
    interpret mode on CPU.
    """
    interpret = common.resolve_interpret(interpret)
    B, Hq, D = q.shape
    ps = pool.page_size
    Hkv = pool.k_pool.shape[2]
    G = Hq // Hkv
    T = tables.shape[1]
    if kv_partitions is None:
        from repro.kernels import planning  # lazy: keep module load light

        kv_partitions = planning.choose_kv_partitions(B, Hkv, T)
    S = max(1, min(int(kv_partitions), T))
    if T % S:
        raise ValueError(
            f"kv_partitions={S} must divide the table length T={T} "
            f"(choose_kv_partitions only returns divisors)")
    P = T // S

    # host-side prep, mirroring the gather path's dtype policy exactly:
    # q pre-scaled in fp32 then cast to the cache compute dtype
    compute_dtype = jnp.dtype(out_dtype)
    qg = (q.reshape(B, Hkv, G, D).astype(jnp.float32)
          * (D ** -0.5)).astype(compute_dtype)
    bt = jnp.where(tables < 0, 0, tables).astype(jnp.int32)   # NULL_BLOCK=0
    qpos = pos.astype(jnp.int32)

    stage = kv_stage_for(pool, fmt)
    operands = stage.operands()
    n_stage = len(operands)

    def slot(bh):
        return bh // Hkv

    def head(bh):
        return bh % Hkv

    def page(bh, s, p, tbl, _):
        return tbl[slot(bh), s * P + p]

    in_specs = [pl.BlockSpec((1, 1, G, D),
                             lambda bh, s, p, tbl, pp:
                             (slot(bh), head(bh), 0, 0))]
    for shape in stage.block_shapes(ps, D):
        if len(shape) == 4:           # payload pool (nb, ps, Hkv, D)
            in_specs.append(pl.BlockSpec(
                shape, lambda bh, s, p, tbl, pp:
                (page(bh, s, p, tbl, pp), 0, head(bh), 0)))
        else:                         # scale pool (nb, ps, Hkv)
            in_specs.append(pl.BlockSpec(
                shape, lambda bh, s, p, tbl, pp:
                (page(bh, s, p, tbl, pp), 0, head(bh))))
    in_specs.append(pl.BlockSpec(                  # page_pos tags (nb, ps)
        (1, ps), lambda bh, s, p, tbl, pp: (page(bh, s, p, tbl, pp), 0)))

    def part_spec(last):
        return pl.BlockSpec((1, 1, 1, G, last),
                            lambda bh, s, p, tbl, pp:
                            (slot(bh), head(bh), s, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * Hkv, S, P),
        in_specs=in_specs,
        out_specs=[part_spec(D), part_spec(LANES), part_spec(LANES)],
        scratch_shapes=[
            pltpu.VMEM((G, LANES), jnp.float32),      # running max
            pltpu.VMEM((G, LANES), jnp.float32),      # running denom
            pltpu.VMEM((G, D), jnp.float32),          # unnormalized acc
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        _make_kernel(stage, Hkv=Hkv, P=P, window=window, n_stage=n_stage,
                     compute_dtype=compute_dtype),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, S, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, G, LANES), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, G, LANES), jnp.float32),
        ],
        compiler_params=common.compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, qpos, qg, *operands, pool.page_pos)

    # combine epilogue: merge the S partitions' (acc, m, l) and normalize —
    # at S == 1 this is exactly the in-kernel flash normalization
    m_p = m_part[..., 0]                               # (B, Hkv, S, G)
    l_p = l_part[..., 0]
    m_max = jnp.max(m_p, axis=2)                       # (B, Hkv, G)
    alpha = jnp.exp(m_p - m_max[:, :, None])           # (B, Hkv, S, G)
    l_tot = jnp.sum(l_p * alpha, axis=2)               # (B, Hkv, G)
    acc = jnp.sum(o_part * alpha[..., None], axis=2)   # (B, Hkv, G, D)
    out = acc / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(B, Hq, D).astype(q.dtype)
