"""Analytic block-size autotuner for the fused W4A16 kernel.

No hardware timing is available in this container, so candidates are ranked
by the TPU v5e cost model under a hard VMEM-budget constraint — the same
"reason from the lowered working set" methodology as EXPERIMENTS.md §Perf:

  * VMEM working set (double-buffered inputs + fp32 accumulator) must fit;
  * MXU dims want 128-alignment (lane width) and big K blocks amortize the
    per-block dequant;
  * grid shape balances against megacore parallelism via the wave model.

Returns (block_m, block_n, block_k, split_k) for a given GEMM shape.
"""
from __future__ import annotations

import functools
from typing import Tuple

from repro.core.costmodel import TPU_V5E
from repro.kernels import common

# Both re-exported from kernels/common.py — the one budget and working-set
# model, shared with the template's block chooser (template.choose_blocks),
# which enforces the budget at kernel-launch time, not just here.
VMEM_BUDGET = common.VMEM_BUDGET
vmem_working_set = common.vmem_working_set

NUM_PARALLEL = 2                   # TensorCores per chip (megacore)


def _score(M, N, K, bm, bn, bk, split_k):
    """Estimated kernel time: HBM traffic + dequant + wave quantization."""
    ks = K // split_k
    n_m, n_n, n_k = -(-M // bm), -(-N // bn), ks // bk
    tiles = n_m * n_n * split_k
    waves = -(-tiles // NUM_PARALLEL)
    eff = tiles / (waves * NUM_PARALLEL)
    flops = 2 * M * N * K
    t_compute = flops / (TPU_V5E.flops * eff)
    # x re-read per N tile; packed W re-read per M tile; partials out
    traffic = (2 * M * K * n_n + 0.5 * K * N * n_m
               + (4 * split_k if split_k > 1 else 2) * M * N)
    t_mem = traffic / TPU_V5E.hbm_bw
    return max(t_compute, t_mem)


@functools.lru_cache(maxsize=4096)
def autotune_w4a16(M: int, N: int, K: int,
                   group: int = 128) -> Tuple[int, int, int, int]:
    """Best (bm, bn, bk, split_k) under the VMEM budget."""
    best = None
    bm = common.largest_divisor(max(M, 8), 128)
    for bn in (128, 256, 512):
        if N % bn:
            continue
        for bk in (256, 512, 1024, 2048):
            if K % bk or not (bk % group == 0 or group % bk == 0):
                continue
            if vmem_working_set(bm, bk=bk, bn=bn, group=group) > VMEM_BUDGET:
                continue
            for s in (1, 2, 4, 8):
                if K % (s * bk) and (K // s) % bk:
                    continue
                if K % s or (K // s) % bk:
                    continue
                t = _score(M, N, K, bm, bn, bk, s)
                if best is None or t < best[0]:
                    best = (t, bm, bn, bk, s)
    if best is None:                          # odd shapes: conservative
        return (bm, common.pick_block(N, 256), common.pick_block(K, 512), 1)
    return best[1:]
