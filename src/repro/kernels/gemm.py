"""Native FP16/BF16 tiled GEMM Pallas kernel — the "PyTorch FP16×FP16" baseline.

Grid ``(M/bm, N/bn, K/bk)``, k innermost; fp32 accumulation in a VMEM scratch
(the L0C analogue), downcast on the final k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """``x @ w`` with explicit BlockSpec VMEM tiling. x:(M,K), w:(K,N)."""
    out_dtype = out_dtype or x.dtype
    interpret = common.resolve_interpret(interpret)
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)

    bm = common.largest_divisor(M, block_m) if M % common.SUBLANE == 0 else M
    if M % common.SUBLANE:
        x = common.pad_dim(x, 0, common.SUBLANE)
        Mp = x.shape[0]
        bm = common.largest_divisor(Mp, block_m)
    else:
        Mp = M
    bn = common.pick_block(N, block_n)
    bk = common.pick_block(K, block_k)

    grid = (Mp // bm, N // bn, K // bk)
    out = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=common.compiler_params(
            ("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w)
    return out[:M]
