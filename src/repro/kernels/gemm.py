"""Native FP16/BF16 tiled GEMM — the "PyTorch FP16×FP16" baseline.

A thin composition over the stage template (kernels/template.py):
identity weight stage + float MXU contraction, data-parallel launch.
Grid ``(M/bm, N/bn, K/bk)``, k innermost; fp32 accumulation in a VMEM
scratch (the L0C analogue), downcast on the final k step.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import template


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """``x @ w`` with explicit BlockSpec VMEM tiling. x:(M,K), w:(K,N)."""
    K2, N = w.shape
    assert x.shape[1] == K2, (x.shape, w.shape)
    return template.tiled_matmul(
        x,
        template.DenseWeight(w),
        template.FloatContraction(),
        N=N,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype or x.dtype,
        interpret=interpret,
    )
