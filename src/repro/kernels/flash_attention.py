"""Flash attention as a Pallas TPU kernel (prefill/training hot spot).

Online-softmax tiling: grid ``(B·Hq, S/bq, Skv/bk)`` with the KV axis
innermost; running max/denominator/accumulator live in VMEM scratch (the
L0C role), the output block is written on the last KV step. GQA is handled
in the index maps (query head → kv head), so K/V are never materialized at
Hq width. Causal + sliding-window masking is positional (iota-based), which
keeps the same kernel correct for the SWA architectures.

The pure-jnp oracle is ``ref.attention_ref``; the chunked online-softmax in
models/attention.py computes the identical function and remains the
CPU/dry-run path (see DESIGN.md §Hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

NEG_INF = -1e30
LANES = 128


def _make_kernel(scale: float, causal: bool, window: int,
                 cq: int, ck: int, s_q: int, s_kv: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        iq = pl.program_id(1)
        ik = pl.program_id(2)

        @pl.when(ik == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0]                                  # (cq, D)
        k = k_ref[0]                                  # (ck, D)
        v = v_ref[0]                                  # (ck, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (cq, ck)

        qpos = iq * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
        kpos = ik * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
        mask = kpos < s_kv                             # kv padding
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                          # (cq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (cq, ck)
        corr = jnp.exp(m_prev - m_new)                 # (cq, 1)
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

        @pl.when(ik == pl.num_programs(2) - 1)
        def _flush():
            o_ref[0] = (acc_ref[...]
                        / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,                 # (B, Sq, Hq, D)
    k: jax.Array,                 # (B, Skv, Hkv, D)
    v: jax.Array,                 # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    interpret=None,
) -> jax.Array:
    interpret = common.resolve_interpret(interpret)
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5

    # (B·Hq, S, D) layout; KV stays at Hkv width (GQA via index map)
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)

    cq = min(block_q, Sq)
    ck = min(block_kv, Skv)
    qh = common.pad_dim(qh, 1, cq)
    kh = common.pad_dim(kh, 1, ck)
    vh = common.pad_dim(vh, 1, ck)
    nq = qh.shape[1] // cq
    nk = kh.shape[1] // ck

    def kv_row(bh):
        return (bh // Hq) * Hkv + (bh % Hq) // G

    grid = (B * Hq, nq, nk)
    out = pl.pallas_call(
        _make_kernel(scale, causal, window, cq, ck, Sq, Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, ck, D), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
            pl.BlockSpec((1, ck, D), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, cq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qh.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq, LANES), jnp.float32),     # running max
            pltpu.VMEM((cq, LANES), jnp.float32),     # running denom
            pltpu.VMEM((cq, D), jnp.float32),         # output accumulator
        ],
        compiler_params=common.compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :Sq]
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
