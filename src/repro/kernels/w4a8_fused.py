"""Fused W4A8 GEMM — int8 MXU dot with in-VMEM INT4 unpack.

The Pallas execution path for ``w4a8_*`` formats (LiquidGEMM-style W4A8,
see PAPERS.md), replacing the XLA-only ``w4a8_xla`` reference path as the
planned strategy on TPU:

  1. activations are dynamically quantized per token to INT8 outside the
     kernel (``quantize_activations_int8`` — one scale per row);
  2. the weight stage unpacks packed INT4 nibbles to an INT8 tile in VMEM
     (no float dequant — scales stay symbolic);
  3. the contraction runs int8×int8 MXU dots with
     ``preferred_element_type=int32`` — exact integer accumulation within
     each scale group — and rescales by the group scale at the group
     boundary into the fp32 accumulator;
  4. the epilogue applies the per-token activation scale and downcasts.

Weight HBM traffic is the packed K·N/2 bytes plus the scale rows, and the
activation read is half the fp16 bytes — the format the paper's memory-
bottleneck analysis points to once weights alone stop being the wall.
"""
from __future__ import annotations

import functools

import jax

from repro.core.quant import QuantizedTensor, quantize_activations_int8
from repro.kernels import template


@functools.partial(
    jax.jit,
    static_argnames=(
        "split_k", "block_m", "block_n", "block_k", "out_dtype", "interpret",
    ),
)
def w4a8_fused(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    split_k: int = 1,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """C = (s_x · x_q) · Dequant(W) with integer accumulation. x:(M,K) float.

    Matches ``w4a8_matmul_ref`` (same dynamic activation quantization, same
    group-boundary rescale) up to fp32 summation order.
    """
    K = x.shape[1]
    assert K == qt.K, (x.shape, qt.shape)
    if qt.format.packing != "int4_pairs_k":
        raise ValueError(
            f"w4a8_fused needs int4_pairs_k packing, got format "
            f"{qt.format.name!r} ({qt.format.packing})")
    xq, xs = quantize_activations_int8(x)
    return template.tiled_matmul(
        xq,
        template.GroupedInt4Raw(qt.packed, qt.scales, qt.zeros),
        template.Int8GroupContraction(),
        N=qt.N,
        group_size=qt.group_size,
        split_k=split_k,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype or x.dtype,
        finalize=lambda y: y * xs,          # per-token epilogue rescale
        interpret=interpret,
    )
