"""Backwards-compatible kernel entry points over the plan-based API.

``w4a16_matmul(x, qt, strategy=...)`` predates the problem/plan redesign
and survives as a thin shim: it builds a :class:`~repro.kernels.planning.
MatmulProblem`, asks the planner for a :class:`~repro.kernels.planning.
KernelPlan` (forcing the strategy/split_k kwargs when given), and executes.
New code should use the primary path directly::

    from repro.kernels import planning
    problem = planning.MatmulProblem.from_operands(x, qt)
    y = planning.execute(planning.plan_matmul(problem), x, qt)

Strategies (all registered in planning.py — add more with
``@register_strategy``, no dispatcher edits needed):

  "fused"       — TPU-native in-VMEM dequant (beyond-paper; wins on TPU)
  "decoupled"   — paper-faithful 3-phase Ascend pipeline through HBM
  "reference"   — pure-jnp oracle (XLA fuses as it pleases)
  "xla"         — dequantize once via XLA then a single jnp.dot
  "w8a16_fused" — per-channel INT8 dequant in VMEM (w8a16_channel formats)
  "w4a8_xla"    — dynamic int8-activation reference path (w4a8_* formats)
  "w4a8_fused"  — int8 MXU dot + int32 accumulate Pallas kernel (w4a8_*)
  "auto"        — cost-model planner ranks every registered strategy that
                  supports the tensor's QuantFormat (see core/quant.py)

Every Pallas strategy above is a stage composition over
``kernels/template.py`` — see docs/kernels.md for the stage architecture
and the add-a-format recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core.quant import QuantizedTensor
from repro.kernels import planning
from repro.kernels.gemm import gemm
from repro.kernels.planning import choose_split_k
from repro.kernels.w4a16_decoupled import (
    dequant_w4,
    reduce_partials,
    splitk_gemm,
    w4a16_decoupled,
)
from repro.kernels.w4a8_fused import w4a8_fused
from repro.kernels.w4a16_fused import w4a16_fused
from repro.kernels.w8a16_fused import w8a16_fused

__all__ = [
    "w4a16_matmul", "gemm", "w4a16_fused", "w4a16_decoupled",
    "w8a16_fused", "w4a8_fused",
    "dequant_w4", "splitk_gemm", "reduce_partials", "choose_split_k",
]


def w4a16_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    strategy: str = "auto",
    split_k: Optional[int] = None,
    autotune: bool = False,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """C = x · Dequant(W). x may have arbitrary leading dims; contracts last.

    Compatibility shim: "auto" defers to the planner (split_k heuristic,
    plan cache); a named strategy is forced with split_k defaulting to 1
    exactly as the old dispatcher did; ``autotune=True`` maps to the
    planner's refine pass (tile search).
    """
    problem = planning.MatmulProblem.from_operands(
        x, qt, out_dtype=out_dtype or x.dtype)
    if strategy == "auto":
        plan = planning.plan_matmul(problem, refine=autotune)
        if split_k is not None:
            plan = dataclasses.replace(plan, split_k=split_k)
    else:
        plan = planning.plan_matmul(problem, strategy=strategy,
                                    refine=autotune)
        if not autotune:
            plan = dataclasses.replace(
                plan, split_k=1 if split_k is None else split_k)
    return planning.execute(plan, x, qt, interpret=interpret)
