"""Public jit'd entry points for the W4A16 kernels with strategy dispatch.

``w4a16_matmul(x, qt, strategy=...)`` is the framework-facing API every
quantized layer calls. Strategies:

  "fused"     — TPU-native in-VMEM dequant (beyond-paper; default on TPU)
  "decoupled" — paper-faithful 3-phase Ascend pipeline through HBM
  "reference" — pure-jnp oracle (XLA fuses as it pleases)
  "xla"       — dequantize once via XLA then a single jnp.dot
  "auto"      — fused, with split_k chosen by the cost-model heuristic

The ``split_k`` heuristic mirrors the paper's finding: split K when the
output tile count M/m · N/n underfills the cores (K ≫ N, small M — the LLM
decode regime).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, dequantize
from repro.kernels import ref
from repro.kernels.gemm import gemm
from repro.kernels.w4a16_decoupled import (
    dequant_w4,
    reduce_partials,
    splitk_gemm,
    w4a16_decoupled,
)
from repro.kernels.w4a16_fused import w4a16_fused

__all__ = [
    "w4a16_matmul", "gemm", "w4a16_fused", "w4a16_decoupled",
    "dequant_w4", "splitk_gemm", "reduce_partials", "choose_split_k",
]

NUM_CORES = 8  # per-chip parallel-unit proxy (v5e TensorCores × futures)


def choose_split_k(M: int, N: int, K: int, *, group_size: int = 128,
                   block_m: int = 128, block_n: int = 256) -> int:
    """Paper-informed Split-K heuristic: split when output tiles underfill
    the chip and K is deep (K ≫ N — decode GEMMs)."""
    m_tiles = max(1, -(-M // block_m))
    n_tiles = max(1, -(-N // block_n))
    tiles = m_tiles * n_tiles
    if tiles >= NUM_CORES or K < 2 * group_size:
        return 1
    want = min(NUM_CORES // tiles, K // group_size)
    s = 1
    while s * 2 <= want and K % (s * 2) == 0 and (K // (s * 2)) % group_size == 0:
        s *= 2
    return s


def w4a16_matmul(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    strategy: str = "auto",
    split_k: Optional[int] = None,
    autotune: bool = False,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """C = x · Dequant(W). x may have arbitrary leading dims; contracts last."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]

    if strategy == "auto":
        # the Pallas kernel is the TPU deployment path (per-shard under
        # shard_map); on CPU hosts "auto" resolves to the XLA formulation —
        # interpret-mode kernels inside a large jit graph would execute the
        # grid as a Python-level loop
        strategy = "fused" if jax.default_backend() == "tpu" else "xla"
        if split_k is None:
            split_k = choose_split_k(M, qt.N, K, group_size=qt.group_size)
    if split_k is None:
        split_k = 1

    if strategy == "reference":
        out = ref.w4a16_ref(x2, qt, out_dtype=out_dtype)
    elif strategy == "xla":
        # barrier pins dequantization INSIDE the enclosing (layer) loop:
        # without it XLA's loop-invariant code motion hoists Dequant(W) for
        # every scanned layer out of the decode loop and materializes the
        # whole model in bf16 — silently undoing W4A16's 4× memory win
        packed, scales = jax.lax.optimization_barrier((qt.packed, qt.scales))
        from repro.core.quant import QuantizedTensor
        qt_pinned = QuantizedTensor(packed, scales, qt.zeros,
                                    qt.group_size, qt.out_dtype)
        w = dequantize(qt_pinned)
        out = jnp.dot(
            x2.astype(w.dtype), w, preferred_element_type=jnp.float32
        ).astype(out_dtype)
    elif strategy == "fused":
        if autotune:
            from repro.kernels.autotune import autotune_w4a16
            bm, bn, bk, s = autotune_w4a16(M, qt.N, K, group=qt.group_size)
            out = w4a16_fused(
                x2, qt, split_k=s, block_m=bm, block_n=bn, block_k=bk,
                out_dtype=out_dtype, interpret=interpret)
        else:
            out = w4a16_fused(
                x2, qt, split_k=split_k, out_dtype=out_dtype,
                interpret=interpret)
    elif strategy == "decoupled":
        out = w4a16_decoupled(
            x2, qt, split_k=max(split_k, 1), out_dtype=out_dtype,
            interpret=interpret,
        )
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return out.reshape(*lead, qt.N)
