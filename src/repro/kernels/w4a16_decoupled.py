"""Paper-faithful decoupled W4A16 pipeline (Ascend Alg. 1 on TPU).

Reproduces the Ascend 910 data-flow *including the global-memory round-trip*
that the paper identifies as the bottleneck:

  Phase 1 (AIV role)  — dequant kernel: INT4 → float weights written to an
                        HBM workspace (the "global workspace buffer").
  Phase 2 (AIC role)  — Split-K tiled GEMM over the fp16/bf16 workspace,
                        producing S fp32 partials in HBM ("split buffers in
                        global memory").
  Phase 3 (AIV role)  — reduce kernel: elementwise sum over S + fp32→fp16
                        downcast.

Each phase is its own ``pallas_call`` so the dequantized weights and the
partials genuinely travel through HBM — this is the variant whose roofline
reproduces the paper's ≤1.48× cap, and the baseline the fused kernel beats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import QuantizedTensor
from repro.kernels import common, template


# ---------------------------------------------------------------------------
# Phase 1: dequant (vector-core role)
# ---------------------------------------------------------------------------

def _make_dequant_kernel(repeat: int, has_zeros: bool):
    def kernel(p_ref, s_ref, *rest):
        if has_zeros:
            z_ref, o_ref = rest
        else:
            z_ref = None
            (o_ref,) = rest
        o_ref[...] = common.dequant_block(
            p_ref, s_ref, z_ref, repeat, o_ref.dtype
        )

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("block_k", "block_n", "out_dtype", "interpret"),
)
def dequant_w4(
    qt: QuantizedTensor,
    *,
    block_k: int = 512,
    block_n: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """Phase-1 kernel: materialize Dequant(W) -> (K, N) in HBM."""
    out_dtype = out_dtype or qt.out_dtype
    interpret = common.resolve_interpret(interpret)
    K, N = qt.K, qt.N
    g = qt.group_size
    bn = common.pick_block(N, block_n)
    bk = common.pick_block(K, block_k)
    while bk > 1 and not (bk % g == 0 or g % bk == 0):
        bk = common.largest_divisor(K, bk - 1)
    repeat = min(bk, g)
    spb = max(1, bk // g)
    has_zeros = qt.zeros is not None

    in_specs = [
        pl.BlockSpec((bk // 2, bn), lambda k, n: (k, n)),
        pl.BlockSpec((spb, bn), lambda k, n: ((k * bk) // g // spb, n)),
    ]
    operands = [qt.packed, qt.scales]
    if has_zeros:
        in_specs.append(pl.BlockSpec((spb, bn), in_specs[1].index_map))
        operands.append(qt.zeros)

    return pl.pallas_call(
        _make_dequant_kernel(repeat, has_zeros),
        grid=(K // bk, N // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bk, bn), lambda k, n: (k, n)),
        out_shape=jax.ShapeDtypeStruct((K, N), out_dtype),
        compiler_params=common.compiler_params(("parallel", "parallel")),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Phase 2: Split-K GEMM over the HBM workspace (cube-core role).
# A template composition: identity weight stage + float contraction, raw
# (S, M, N) partials — phase 3 reduces them through HBM, per the paper.
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("split_k", "block_m", "block_n", "block_k", "interpret"),
)
def splitk_gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    split_k: int = 4,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret=None,
) -> jax.Array:
    """Phase-2 kernel: S fp32 partial products C_i = A · B_i in HBM."""
    K2, N = w.shape
    assert x.shape[1] == K2 and K2 % split_k == 0
    return template.tiled_matmul(
        x,
        template.DenseWeight(w),
        template.FloatContraction(),
        N=N,
        split_k=split_k,
        block_m=block_m, block_n=block_n, block_k=block_k,
        reduce_splits=False,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Phase 3: reduction (vector-core role)
# ---------------------------------------------------------------------------

def _reduce_kernel(p_ref, o_ref):
    o_ref[...] = jnp.sum(p_ref[...], axis=0).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "out_dtype", "interpret")
)
def reduce_partials(
    partials: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 512,
    out_dtype=jnp.bfloat16,
    interpret=None,
) -> jax.Array:
    """Phase-3 kernel: C = sum_i C_i, fp32 → out_dtype."""
    interpret = common.resolve_interpret(interpret)
    S, M, N = partials.shape
    partials = common.pad_dim(partials, 1, common.SUBLANE)
    Mp = partials.shape[1]
    bm = common.largest_divisor(Mp, block_m)
    bn = common.pick_block(N, block_n)

    out = pl.pallas_call(
        _reduce_kernel,
        grid=(Mp // bm, N // bn),
        in_specs=[pl.BlockSpec((S, bm, bn), lambda m, n: (0, m, n))],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
        compiler_params=common.compiler_params(("parallel", "parallel")),
        interpret=interpret,
    )(partials)
    return out[:M]


# ---------------------------------------------------------------------------
# The full 3-phase pipeline (paper Alg. 1)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=(
        "split_k", "block_m", "block_n", "block_k", "out_dtype", "interpret",
    ),
)
def w4a16_decoupled(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    split_k: int = 4,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """C = A · Dequant(W) via the Ascend 3-phase GM-workspace pipeline."""
    out_dtype = out_dtype or x.dtype
    w = dequant_w4(qt, out_dtype=x.dtype, interpret=interpret)     # Phase 1
    partials = splitk_gemm(
        x, w,
        split_k=split_k, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )                                                              # Phase 2
    return reduce_partials(partials, out_dtype=out_dtype, interpret=interpret)
