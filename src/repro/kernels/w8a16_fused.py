"""Fused W8A16 GEMM — per-channel INT8 weights, dequant in VMEM.

The ``w8a16_channel`` counterpart of the fused W4A16 kernel: INT8 weight
rows cross HBM once (K·N bytes, half the fp16 footprint), the per-channel
scale row rides along as a (1, bn) block, and the INT8→float dequant
happens in VMEM right before the MXU contraction — no global-memory
round-trip (the paper's decoupled-architecture penalty does not apply).

A template composition (kernels/template.py): per-channel INT8 dequant
weight stage + float MXU contraction, both data-parallel and Split-K
launch shapes.
"""
from __future__ import annotations

import functools

import jax

from repro.core.quant import QuantizedTensor, per_channel_scales
from repro.kernels import template


@functools.partial(
    jax.jit,
    static_argnames=(
        "split_k", "block_m", "block_n", "block_k", "out_dtype", "interpret",
    ),
)
def w8a16_fused(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    split_k: int = 1,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """C = x · Dequant(W) for per-channel INT8 weights. x:(M,K) float."""
    K = x.shape[1]
    assert K == qt.K, (x.shape, qt.shape)
    if qt.format.packing != "int8_rows":
        raise ValueError(
            f"w8a16_fused needs int8_rows packing, got format "
            f"{qt.format.name!r} ({qt.format.packing})")
    scales, zeros = per_channel_scales(qt)   # (1, N), tensor broadcast too
    return template.tiled_matmul(
        x,
        template.ChannelInt8Dequant(qt.packed, scales, zeros),
        template.FloatContraction(),
        N=qt.N,
        split_k=split_k,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype or x.dtype,
        interpret=interpret,
    )
