"""Shared helpers for the Pallas TPU kernels.

Block-size selection is the TPU analogue of the paper's ``[m, n, k]`` block
parameter (Alg. 1): blocks must fit VMEM (the L1/L0 analogue) and keep the
MXU dimensions 128-aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128          # TPU lane width — minor dim of every block
SUBLANE = 8         # fp32 sublane; bf16 is 16 but 8 keeps blocks legal
VMEM_BUDGET = 96 * 1024 * 1024  # generous interpret-mode budget; real TPU ~128MB v5e? use 96MB guard


def is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret) -> bool:
    """interpret=None → auto (interpret on CPU, compiled on TPU)."""
    if interpret is None:
        return is_cpu()
    return bool(interpret)


def largest_divisor(dim: int, target: int, multiple_of: int = 1) -> int:
    """Largest d ≤ target with dim % d == 0 and d % multiple_of == 0."""
    target = min(target, dim)
    for d in range(target, 0, -1):
        if dim % d == 0 and d % multiple_of == 0:
            return d
    return multiple_of if dim % multiple_of == 0 else 1


def pick_block(dim: int, target: int, align: int = LANE) -> int:
    """Prefer a LANE-aligned divisor of ``dim`` near ``target``."""
    if dim % align == 0:
        d = largest_divisor(dim, target, align)
        if d >= align:
            return d
    return largest_divisor(dim, target)


def pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad ``axis`` of x up to the next multiple."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def compiler_params(dimension_semantics):
    """Best-effort TPU compiler params (ignored under interpret mode)."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        if hasattr(pltpu, "CompilerParams"):
            return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
        return pltpu.TPUCompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover - older/newer API drift
        return None


def dequant_block(packed, scales, zeros, repeat: int, compute_dtype):
    """In-VMEM INT4→float dequant of one weight block (the AIV role, fused).

    packed : (bk//2, bn) int8 — two nibbles per byte along K
    scales : (bk//repeat, bn) float — group scales covering this block
    zeros  : same shape as scales, or None (symmetric)
    returns: (bk, bn) compute_dtype
    """
    b = packed[...]
    lo = jnp.right_shift(jnp.left_shift(b, 4), 4)   # sign-extend low nibble
    hi = jnp.right_shift(b, 4)                      # arithmetic → sign-extended
    k2, bn = b.shape
    q = jnp.stack([lo, hi], axis=1).reshape(2 * k2, bn).astype(jnp.float32)
    s = jnp.repeat(scales[...].astype(jnp.float32), repeat, axis=0)
    if zeros is not None:
        q = q - jnp.repeat(zeros[...].astype(jnp.float32), repeat, axis=0)
    return (q * s).astype(compute_dtype)
