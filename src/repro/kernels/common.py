"""Shared helpers for the Pallas TPU kernels.

Block-size selection is the TPU analogue of the paper's ``[m, n, k]`` block
parameter (Alg. 1): blocks must fit VMEM (the L1/L0 analogue) and keep the
MXU dimensions 128-aligned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128          # TPU lane width — minor dim of every block
SUBLANE = 8         # fp32 sublane; bf16 is 16 but 8 keeps blocks legal

# The one VMEM budget (leave headroom off the ~128MB v5e VMEM). Both the
# autotuner's candidate ranking and the template's block chooser
# (template.choose_blocks) enforce it through vmem_working_set below.
VMEM_BUDGET = 96 * 1024 * 1024


def vmem_working_set(bm: int, bn: int, bk: int, group: int,
                     act_bytes: int = 2, weight_elt_bytes: float = 0.5,
                     has_scales: bool = True,
                     dequant_tile: bool = True) -> int:
    """Bytes resident per grid step (double-buffered ins + fp32 acc).

    Defaults describe the fused W4A16 kernel (packed int4 weights at 0.5
    bytes/element, fp32 group scales, a dequantized tile feeding the MXU).
    Other weight stages override: dense GEMM has ``weight_elt_bytes=
    act_bytes`` and neither scales nor a dequant tile; per-channel INT8 has
    ``weight_elt_bytes=1``.
    """
    x_blk = bm * bk * act_bytes
    w_blk = int(bk * bn * weight_elt_bytes)
    s_blk = max(1, bk // max(group, 1)) * bn * 4 if has_scales else 0
    deq = bk * bn * act_bytes if dequant_tile else 0
    acc = bm * bn * 4
    return 2 * (x_blk + w_blk + s_blk) + deq + acc


def is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret) -> bool:
    """interpret=None → auto (interpret on CPU, compiled on TPU)."""
    if interpret is None:
        return is_cpu()
    return bool(interpret)


def largest_divisor(dim: int, target: int, multiple_of: int = 1) -> int:
    """Largest d ≤ target with dim % d == 0 and d % multiple_of == 0."""
    target = min(target, dim)
    for d in range(target, 0, -1):
        if dim % d == 0 and d % multiple_of == 0:
            return d
    return multiple_of if dim % multiple_of == 0 else 1


def pick_block(dim: int, target: int, align: int = LANE) -> int:
    """Prefer a LANE-aligned divisor of ``dim`` near ``target``."""
    if dim % align == 0:
        d = largest_divisor(dim, target, align)
        if d >= align:
            return d
    return largest_divisor(dim, target)


def pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad ``axis`` of x up to the next multiple."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def compiler_params(dimension_semantics):
    """Best-effort TPU compiler params (ignored under interpret mode)."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        if hasattr(pltpu, "CompilerParams"):
            return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
        return pltpu.TPUCompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover - older/newer API drift
        return None


def unpack_int4_block(packed) -> jax.Array:
    """In-VMEM INT4→INT8 unpack of one packed weight block (no scaling).

    packed : (bk//2, bn) int8 ref/array — two nibbles per byte along K
    returns: (bk, bn) int8 in [-8, 7]

    Shift-based sign extension lowers to cheap VPU ops; the raw int8 tile
    either feeds a float dequant (:func:`dequant_block`) or goes straight
    into an int8×int8 MXU dot (the W4A8 contraction stage).
    """
    b = packed[...]
    lo = jnp.right_shift(jnp.left_shift(b, 4), 4)   # sign-extend low nibble
    hi = jnp.right_shift(b, 4)                      # arithmetic → sign-extended
    k2, bn = b.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, bn)


def dequant_block(packed, scales, zeros, repeat: int, compute_dtype):
    """In-VMEM INT4→float dequant of one weight block (the AIV role, fused).

    packed : (bk//2, bn) int8 — two nibbles per byte along K
    scales : (bk//repeat, bn) float — group scales covering this block
    zeros  : same shape as scales, or None (symmetric)
    returns: (bk, bn) compute_dtype
    """
    q = unpack_int4_block(packed).astype(jnp.float32)
    s = jnp.repeat(scales[...].astype(jnp.float32), repeat, axis=0)
    if zeros is not None:
        q = q - jnp.repeat(zeros[...].astype(jnp.float32), repeat, axis=0)
    return (q * s).astype(compute_dtype)


def dequant_channel_block(rows, scales, zeros, compute_dtype):
    """In-VMEM per-channel INT8→float dequant of one weight block.

    rows   : (bk, bn) int8 ref/array — weight rows stored directly
    scales : (1, bn) float — one scale per output channel
    zeros  : same shape as scales, or None (symmetric)
    returns: (bk, bn) compute_dtype
    """
    q = rows[...].astype(jnp.float32)
    if zeros is not None:
        q = q - zeros[...].astype(jnp.float32)
    return (q * scales[...].astype(jnp.float32)).astype(compute_dtype)
