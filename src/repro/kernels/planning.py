"""Plan-based W4A16 matmul API: problem → plan → execute.

The paper's central finding is that W4A16 wins or loses on *dispatch
decisions* — Split-K degree, tile shapes, and whether the dequant
round-trips through global memory. This module makes those decisions
first-class objects instead of string branches and scattered kwargs:

  :class:`MatmulProblem`  — a hashable description of one GEMM
                            (shapes, dtypes, quantization, backend).
  :class:`KernelPlan`     — a serializable dispatch decision
                            (strategy + split_k + tile shape).
  registry                — ``@register_strategy("name")`` makes a strategy
                            pluggable; the planner ranks whatever is
                            registered by its cost model, so adding a
                            backend never edits a dispatcher.
  :func:`plan_matmul`     — cost-model planner folding the Split-K
                            occupancy heuristic and the roofline models of
                            ``core/costmodel.py`` into one ranked decision,
                            memoized in a JSON-persistent plan cache.
  :func:`execute`         — run a plan on concrete operands.

Primary path (what every in-repo call site uses)::

    problem = MatmulProblem.from_operands(x, qt)
    y = execute(plan_matmul(problem), x, qt)

``ops.w4a16_matmul(x, qt, strategy=...)`` remains as a thin
backwards-compatible shim over this module. See docs/api.md.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import threading
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compat  # noqa: F401  (registers vmap rules "xla" needs)
from repro.core import costmodel
from repro.core.quant import QuantizedTensor, dequantize
from repro.kernels import ref
from repro.kernels.w4a16_decoupled import w4a16_decoupled
from repro.kernels.w4a16_fused import w4a16_fused

__all__ = [
    "MatmulProblem", "KernelPlan", "Strategy",
    "register_strategy", "get_strategy", "available_strategies",
    "plan_matmul", "resolve_plan", "execute",
    "PlanCache", "PLAN_CACHE", "load_plan_cache", "save_plan_cache",
    "choose_split_k", "num_cores",
]


# ---------------------------------------------------------------------------
# Problem
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatmulProblem:
    """One W4A16 GEMM: C[M, N] = A[M, K] · Dequant(W[K, N]).

    Hashable and order-insensitive — the plan cache and the planner key on
    this. ``batch`` counts independent GEMMs sharing the plan (vmapped
    expert stacks); ``M`` is rows per GEMM.
    """

    M: int
    N: int
    K: int
    group_size: int = 128
    act_dtype: str = "bfloat16"
    out_dtype: str = "bfloat16"
    has_zeros: bool = False
    backend: str = "cpu"
    batch: int = 1

    @classmethod
    def from_operands(cls, x: jax.Array, qt: QuantizedTensor, *,
                      out_dtype=None, backend: Optional[str] = None,
                      batch: int = 1) -> "MatmulProblem":
        """Describe ``x @ Dequant(qt)``; x may have arbitrary leading dims."""
        K = x.shape[-1]
        M = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
        return cls(
            M=int(M), N=int(qt.N), K=int(K),
            group_size=int(qt.group_size),
            act_dtype=str(jnp.dtype(x.dtype)),
            out_dtype=str(jnp.dtype(out_dtype or x.dtype)),
            has_zeros=qt.zeros is not None,
            backend=backend or jax.default_backend(),
            batch=batch,
        )

    @property
    def layer_key(self) -> str:
        """Weight-shape key ("KxN") — one entry per model layer."""
        return f"{self.K}x{self.N}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "MatmulProblem":
        return cls(**dict(d))


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """A dispatch decision: which strategy, how to split K, which tiles.

    ``out_dtype`` of None means "the activation dtype at execute time".
    JSON round-trips exactly (see to_json/from_json).
    """

    strategy: str
    split_k: int = 1
    block_m: int = 128
    block_n: int = 256
    block_k: int = 512
    out_dtype: Optional[str] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "KernelPlan":
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "KernelPlan":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Strategy:
    """A pluggable execution strategy.

    execute(x2, qt, plan, interpret=None) -> (M, N) array, x2 always 2-D.
    cost(problem, plan) -> estimated seconds (planner ranking).
    supports(problem) -> eligibility gate.
    """

    name: str
    execute: Callable[..., jax.Array]
    cost: Callable[[MatmulProblem, KernelPlan], float]
    supports: Callable[[MatmulProblem], bool]


_REGISTRY: Dict[str, Strategy] = {}


def register_strategy(name: str, *, cost=None, supports=None):
    """Register an execute fn under ``name``; the planner picks it up with
    no dispatcher edits. ``cost`` defaults to +inf (never auto-chosen,
    still explicitly runnable); ``supports`` defaults to always-eligible."""

    def deco(fn):
        _REGISTRY[name] = Strategy(
            name=name,
            execute=fn,
            cost=cost or (lambda problem, plan: float("inf")),
            supports=supports or (lambda problem: True),
        )
        return fn

    return deco


def get_strategy(name: str) -> Strategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {available_strategies()}"
        ) from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


# ---------------------------------------------------------------------------
# Split-K heuristic (paper Fig. 2) and core counting
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def num_cores() -> int:
    """Parallel-unit count for the occupancy heuristic: on TPU, the local
    chips × 2 TensorCores (megacore); elsewhere the paper-model default of
    8 — a CPU host is modeling the target chip, not itself."""
    try:
        dev = jax.local_devices()[0]
        if dev.platform == "tpu":
            return max(1, jax.local_device_count() * 2)
    except Exception:  # pragma: no cover - no devices during docs builds
        pass
    return 8


def choose_split_k(M: int, N: int, K: int, *, group_size: int = 128,
                   block_m: int = 128, block_n: int = 256) -> int:
    """Paper-informed Split-K heuristic: split when output tiles underfill
    the chip and K is deep (K ≫ N — decode GEMMs)."""
    if group_size <= 0 or K % group_size:
        return 1          # K-slices could not stay group-aligned
    cores = num_cores()
    m_tiles = max(1, -(-M // block_m))
    n_tiles = max(1, -(-N // block_n))
    tiles = m_tiles * n_tiles
    if tiles >= cores or K < 2 * group_size:
        return 1
    want = min(cores // tiles, K // group_size)
    s = 1
    while s * 2 <= want and K % (s * 2) == 0 and (K // (s * 2)) % group_size == 0:
        s *= 2
    return s


# ---------------------------------------------------------------------------
# Cost models (seconds; lower wins). Pallas strategies pay a large factor
# off-TPU: interpret mode executes the grid as a Python loop, so the
# planner must never auto-pick them on a CPU host.
# ---------------------------------------------------------------------------

_INTERPRET_PENALTY = 1e4


def _pallas_factor(problem: MatmulProblem) -> float:
    return 1.0 if problem.backend == "tpu" else _INTERPRET_PENALTY


def _cost_fused(problem: MatmulProblem, plan: KernelPlan) -> float:
    return (costmodel.w4a16_time_tpu_fused(problem.M, problem.N, problem.K)
            * problem.batch * _pallas_factor(problem))


def _cost_decoupled(problem: MatmulProblem, plan: KernelPlan) -> float:
    return (costmodel.w4a16_time_tpu_decoupled(
        problem.M, problem.N, problem.K, split_k=max(plan.split_k, 1))
        * problem.batch * _pallas_factor(problem))


def _cost_xla(problem: MatmulProblem, plan: KernelPlan) -> float:
    """Dequant materialized once by XLA (int4 read + float write) + GEMM."""
    M, N, K = problem.M, problem.N, problem.K
    spec = costmodel.TPU_V5E
    t_deq = (0.5 * K * N + 2 * K * N) / spec.hbm_bw
    t_mm = max((2 * M * N * K) / spec.flops,
               (2 * M * K + 2 * K * N + 2 * M * N) / spec.hbm_bw)
    return (t_deq + t_mm) * problem.batch


def _cost_reference(problem: MatmulProblem, plan: KernelPlan) -> float:
    # same math as "xla" but without the loop-invariance barrier — XLA may
    # hoist the dequant and re-materialize the model in bf16; keep it as a
    # correctness oracle, never the planner's pick
    return _cost_xla(problem, plan) * 1.25


def _supports_pallas(problem: MatmulProblem) -> bool:
    # the kernels pad M and re-pick blocks, but K must be packable/grouped
    return problem.K % 2 == 0 and problem.K % problem.group_size == 0


# ---------------------------------------------------------------------------
# Registered strategies. "decoupled" (the paper-faithful pipeline) plugs in
# through the same decorator as everything else — the acceptance demo that
# a strategy needs no dispatcher edits.
# ---------------------------------------------------------------------------

def _exec_out_dtype(plan: KernelPlan, x: jax.Array):
    return jnp.dtype(plan.out_dtype) if plan.out_dtype else x.dtype


@register_strategy("reference", cost=_cost_reference)
def _run_reference(x2, qt, plan, *, interpret=None):
    return ref.w4a16_ref(x2, qt, out_dtype=_exec_out_dtype(plan, x2))


@register_strategy("xla", cost=_cost_xla)
def _run_xla(x2, qt, plan, *, interpret=None):
    # barrier pins dequantization INSIDE the enclosing (layer) loop:
    # without it XLA's loop-invariant code motion hoists Dequant(W) for
    # every scanned layer out of the decode loop and materializes the
    # whole model in bf16 — silently undoing W4A16's 4× memory win
    pinned = jax.lax.optimization_barrier(
        (qt.packed, qt.scales) + (() if qt.zeros is None else (qt.zeros,)))
    packed, scales = pinned[0], pinned[1]
    zeros = pinned[2] if qt.zeros is not None else None
    w = dequantize(QuantizedTensor(packed, scales, zeros,
                                   qt.group_size, qt.out_dtype))
    return jnp.dot(
        x2.astype(w.dtype), w, preferred_element_type=jnp.float32
    ).astype(_exec_out_dtype(plan, x2))


@register_strategy("fused", cost=_cost_fused, supports=_supports_pallas)
def _run_fused(x2, qt, plan, *, interpret=None):
    return w4a16_fused(
        x2, qt, split_k=max(plan.split_k, 1),
        block_m=plan.block_m, block_n=plan.block_n, block_k=plan.block_k,
        out_dtype=_exec_out_dtype(plan, x2), interpret=interpret)


@register_strategy("decoupled", cost=_cost_decoupled,
                   supports=_supports_pallas)
def _run_decoupled(x2, qt, plan, *, interpret=None):
    return w4a16_decoupled(
        x2, qt, split_k=max(plan.split_k, 1),
        block_m=plan.block_m, block_n=plan.block_n, block_k=plan.block_k,
        out_dtype=_exec_out_dtype(plan, x2), interpret=interpret)


# ---------------------------------------------------------------------------
# Plan cache (process-wide, JSON-persistent)
# ---------------------------------------------------------------------------

class PlanCache:
    """Problem → plan memo with hit/miss stats and JSON persistence.

    Only planner-chosen (strategy-unforced) plans are cached; forced or
    overridden plans are cheap to rebuild and would poison lookups.
    """

    _VERSION = 1

    def __init__(self) -> None:
        self._plans: Dict[MatmulProblem, KernelPlan] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._plans)

    def get(self, problem: MatmulProblem) -> Optional[KernelPlan]:
        with self._lock:
            plan = self._plans.get(problem)
            if plan is None:
                self.misses += 1
            else:
                self.hits += 1
            return plan

    def put(self, problem: MatmulProblem, plan: KernelPlan) -> None:
        with self._lock:
            self._plans[problem] = plan

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self.hits = self.misses = 0

    def save(self, path: str) -> int:
        """Persist every cached decision; returns the entry count."""
        with self._lock:
            entries = [{"problem": prob.to_dict(), "plan": plan.to_dict()}
                       for prob, plan in self._plans.items()]
        with open(path, "w") as f:
            json.dump({"version": self._VERSION, "plans": entries},
                      f, indent=1, sort_keys=True)
        return len(entries)

    def load(self, path: str, *, merge: bool = True) -> int:
        """Load persisted decisions (merging over the current contents by
        default); returns the number of entries loaded. Any malformed
        content raises ValueError (never TypeError/AttributeError), so
        callers can guard with one exception type."""
        with open(path) as f:
            blob = json.load(f)      # JSONDecodeError is a ValueError
        try:
            if blob.get("version") != self._VERSION:
                raise ValueError(
                    f"unsupported plan-cache version in {path}: "
                    f"{blob.get('version')!r}")
            loaded = {MatmulProblem.from_dict(e["problem"]):
                      KernelPlan.from_dict(e["plan"]) for e in blob["plans"]}
        except (TypeError, AttributeError, KeyError) as e:
            raise ValueError(f"malformed plan cache {path}: {e}") from e
        # a cache written by a build with extra strategies must not smuggle
        # un-executable plans past tolerant loading: keep only entries this
        # process can actually dispatch
        loaded = {prob: plan for prob, plan in loaded.items()
                  if plan.strategy in _REGISTRY}
        with self._lock:
            if not merge:
                self._plans.clear()
            self._plans.update(loaded)
        return len(loaded)


PLAN_CACHE = PlanCache()


def load_plan_cache(path: str, *, merge: bool = True,
                    tolerant: bool = False) -> int:
    """Load ``path`` into the process cache. With ``tolerant=True`` a
    missing or unreadable file is a no-op returning -1 — launchers warm-
    starting from an optional cache must never die on a stale file."""
    try:
        return PLAN_CACHE.load(path, merge=merge)
    except (OSError, ValueError):
        if tolerant:
            return -1
        raise


def save_plan_cache(path: str) -> int:
    return PLAN_CACHE.save(path)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def _default_plan(problem: MatmulProblem, strategy: str,
                  refine: bool) -> KernelPlan:
    """Heuristic (or refined) plan parameters for one strategy."""
    split_k = 1
    block_m, block_n, block_k = 128, 256, 512
    if strategy in ("fused", "decoupled"):
        split_k = choose_split_k(problem.M, problem.N, problem.K,
                                 group_size=problem.group_size)
        if refine:
            # the former autotune.py search, now the planner's optional
            # measurement/refinement pass: rank tile candidates under the
            # VMEM budget with the v5e roofline
            from repro.kernels.autotune import autotune_w4a16

            block_m, block_n, block_k, split_k = autotune_w4a16(
                problem.M, problem.N, problem.K, group=problem.group_size)
    return KernelPlan(strategy=strategy, split_k=split_k, block_m=block_m,
                      block_n=block_n, block_k=block_k,
                      out_dtype=problem.out_dtype)


def plan_matmul(problem: MatmulProblem, *, strategy: Optional[str] = None,
                refine: bool = False, use_cache: bool = True,
                cache: Optional[PlanCache] = None) -> KernelPlan:
    """Choose a :class:`KernelPlan` for ``problem``.

    With ``strategy=None`` every registered, eligible strategy is ranked by
    its cost model and the cheapest wins; the decision is memoized in the
    plan cache (process-wide, JSON-persistable). A named ``strategy`` forces
    the choice but still fills split_k/tiles heuristically. ``refine=True``
    additionally runs the tile-search refinement (ex-autotune) for Pallas
    strategies.
    """
    if strategy is not None:
        return _default_plan(problem, get_strategy(strategy).name, refine)

    cache = cache if cache is not None else PLAN_CACHE
    if use_cache and not refine:
        # a refine request must reach the tile search even when a heuristic
        # plan is already cached; the refined plan then overwrites it
        hit = cache.get(problem)
        if hit is not None:
            return hit

    best: Optional[Tuple[float, int, KernelPlan]] = None
    for order, strat in enumerate(_REGISTRY.values()):
        if not strat.supports(problem):
            continue
        plan = _default_plan(problem, strat.name, refine)
        score = strat.cost(problem, plan)
        if best is None or (score, order) < (best[0], best[1]):
            best = (score, order, plan)
    if best is None:
        # nothing eligible (e.g. odd K): the pure-jnp oracle always works
        best = (float("inf"), -1, _default_plan(problem, "reference", False))
    plan = best[2]
    if use_cache:
        cache.put(problem, plan)
    return plan


def resolve_plan(problem: MatmulProblem, cfg=None) -> KernelPlan:
    """Plan for a model-layer matmul, honoring config overrides.

    ``cfg.w4a16_plan`` may be a :class:`KernelPlan` (applies to every
    quantized layer), a mapping from layer key ``"KxN"`` to a plan/dict
    (per-layer override), or None. Otherwise ``cfg.w4a16_strategy`` forces
    the strategy ("auto" defers fully to the planner).
    """
    override = getattr(cfg, "w4a16_plan", None) if cfg is not None else None
    if override is not None:
        if isinstance(override, KernelPlan):
            return override
        if isinstance(override, Mapping):
            hit = override.get(problem.layer_key)
            if hit is not None:
                return hit if isinstance(hit, KernelPlan) \
                    else KernelPlan.from_dict(hit)
        elif isinstance(override, str):
            return KernelPlan.from_json(override)
    strategy = getattr(cfg, "w4a16_strategy", "auto") if cfg is not None \
        else "auto"
    if strategy and strategy != "auto":
        return plan_matmul(problem, strategy=strategy)
    return plan_matmul(problem)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def execute(plan: KernelPlan, x: jax.Array, qt: QuantizedTensor, *,
            interpret=None) -> jax.Array:
    """Run a planned W4A16 matmul: x (..., K) → (..., N)."""
    strat = get_strategy(plan.strategy)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = strat.execute(x2, qt, plan, interpret=interpret)
    return out.reshape(*lead, qt.N)


def matmul(x: jax.Array, qt: QuantizedTensor, *, cfg=None,
           interpret=None) -> jax.Array:
    """One-call convenience over the primary path (plan cache included)."""
    problem = MatmulProblem.from_operands(x, qt)
    return execute(resolve_plan(problem, cfg), x, qt, interpret=interpret)


def plan_for_params(params, M: int, *, refine: bool = False,
                    backend: Optional[str] = None) -> Dict[str, KernelPlan]:
    """Pre-plan every quantized layer GEMM in a param pytree for ``M`` rows.

    Returns ``{layer_key ("KxN"): plan}``; every decision lands in the
    process plan cache, so subsequent layer-time lookups (same M/dtypes)
    are hits. ``refine=True`` runs the tile-search refinement per layer —
    the launcher-facing replacement for the old per-call autotune kwarg.
    """
    plans: Dict[str, KernelPlan] = {}
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda t: isinstance(t, QuantizedTensor))
    for leaf in leaves:
        if not isinstance(leaf, QuantizedTensor):
            continue
        K = int(leaf.packed.shape[-2]) * 2
        N = int(leaf.packed.shape[-1])
        # batch=1, matching the layer-time lookup key: stacked (L, ...)
        # kernels execute as 2-D slices inside scan, so from_operands
        # builds batch=1 problems there — and batch scales every cost
        # uniformly, so the decision is stack-size-invariant anyway
        problem = MatmulProblem(
            M=int(M), N=N, K=K, group_size=leaf.group_size,
            act_dtype=str(jnp.dtype(leaf.out_dtype)),
            out_dtype=str(jnp.dtype(leaf.out_dtype)),
            has_zeros=leaf.zeros is not None,
            backend=backend or jax.default_backend())
        plans[problem.layer_key] = plan_matmul(problem, refine=refine)
    return plans
