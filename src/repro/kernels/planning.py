"""Plan-based W4A16 matmul API: problem → plan → execute.

The paper's central finding is that W4A16 wins or loses on *dispatch
decisions* — Split-K degree, tile shapes, and whether the dequant
round-trips through global memory. This module makes those decisions
first-class objects instead of string branches and scattered kwargs:

  :class:`MatmulProblem`  — a hashable description of one GEMM
                            (shapes, dtypes, quantization format, backend).
  :class:`KernelPlan`     — a serializable dispatch decision
                            (strategy + split_k + tile shape).
  registry                — ``@register_strategy("name")`` makes a strategy
                            pluggable; the planner ranks whatever is
                            registered by its cost model, so adding a
                            backend never edits a dispatcher. Strategies
                            declare the :class:`~repro.core.quant.
                            QuantFormat` names they can execute
                            (``formats=`` fnmatch patterns); the planner
                            only considers matching strategies and a forced
                            strategy/format mismatch is refused loudly.
  :func:`plan_matmul`     — cost-model planner folding the Split-K
                            occupancy heuristic and the roofline models of
                            ``core/costmodel.py`` into one ranked decision,
                            memoized in a JSON-persistent plan cache.
  :func:`execute`         — run a plan on concrete operands.

Primary path (what every in-repo call site uses)::

    problem = MatmulProblem.from_operands(x, qt)
    y = execute(plan_matmul(problem), x, qt)

``ops.w4a16_matmul(x, qt, strategy=...)`` remains as a thin
backwards-compatible shim over this module. See docs/api.md.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
import json
import math
import os
import tempfile
import threading
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compat  # noqa: F401  (registers vmap rules "xla" needs)
from repro.core import costmodel
from repro.core.quant import (
    DEFAULT_FORMAT,
    DEFAULT_KV_FORMAT,
    QuantizedTensor,
    dequantize,
    get_kv_format,
    w4a8_matmul_ref,
    w4a16_format_for,
)
from repro.kernels import ref
from repro.kernels.w4a8_fused import w4a8_fused
from repro.kernels.w4a16_decoupled import w4a16_decoupled
from repro.kernels.w4a16_fused import w4a16_fused
from repro.kernels.w8a16_fused import w8a16_fused

__all__ = [
    "MatmulProblem", "KernelPlan", "Strategy",
    "register_strategy", "get_strategy", "available_strategies",
    "strategies_for_format",
    "plan_matmul", "resolve_plan", "execute", "shard_problem",
    "PlanCache", "PLAN_CACHE", "load_plan_cache", "save_plan_cache",
    "choose_split_k", "num_cores",
    "AttentionProblem", "AttentionPlan", "register_attn_path",
    "available_attn_paths", "plan_attention", "choose_kv_partitions",
]


# ---------------------------------------------------------------------------
# Problem
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatmulProblem:
    """One W4A16 GEMM: C[M, N] = A[M, K] · Dequant(W[K, N]).

    Hashable and order-insensitive — the plan cache and the planner key on
    this. ``batch`` counts independent GEMMs sharing the plan (vmapped
    expert stacks); ``M`` is rows per GEMM. ``format`` is the registered
    :class:`~repro.core.quant.QuantFormat` name, so plans cache per-format
    and the planner can filter strategies on the formats they support.
    """

    M: int
    N: int
    K: int
    group_size: int = 128
    act_dtype: str = "bfloat16"
    out_dtype: str = "bfloat16"
    has_zeros: bool = False
    backend: str = "cpu"
    batch: int = 1
    format: str = DEFAULT_FORMAT

    @classmethod
    def from_operands(cls, x: jax.Array, qt: QuantizedTensor, *,
                      out_dtype=None, backend: Optional[str] = None,
                      batch: int = 1) -> "MatmulProblem":
        """Describe ``x @ Dequant(qt)``; x may have arbitrary leading dims."""
        K = x.shape[-1]
        M = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
        return cls(
            M=int(M), N=int(qt.N), K=int(K),
            group_size=int(qt.group_size),
            act_dtype=str(jnp.dtype(x.dtype)),
            out_dtype=str(jnp.dtype(out_dtype or x.dtype)),
            has_zeros=qt.zeros is not None,
            backend=backend or jax.default_backend(),
            batch=batch,
            format=qt.format.name,
        )

    @property
    def layer_key(self) -> str:
        """Weight-shape key ("KxN") — one entry per model layer."""
        return f"{self.K}x{self.N}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "MatmulProblem":
        d = dict(d)
        if "format" not in d:
            # pre-format plan caches: every entry was the W4A16 family —
            # derive the format name the same way the legacy QuantizedTensor
            # constructor does, so old and new keys collide correctly
            try:
                d["format"] = w4a16_format_for(
                    int(d.get("group_size", 128)),
                    symmetric=not d.get("has_zeros", False)).name
            except (TypeError, ValueError):
                d["format"] = DEFAULT_FORMAT
        return cls(**d)


def _mesh_axis_size(mesh, name: str) -> int:
    """Axis size by name; 0 when absent (works on Mesh and spec-level fakes)."""
    try:
        return int(mesh.shape[name])
    except (KeyError, TypeError):
        return 0


def shard_problem(problem: MatmulProblem, mesh, kind: str) -> MatmulProblem:
    """The per-rank LOCAL GEMM of ``problem`` under tensor-parallel sharding.

    Megatron TP shrinks exactly one weight dim per rank: ``kind="row"``
    (wo / w_down / out_proj — input features sharded) divides K by the
    "model" axis, ``kind="col"`` (wq / w_up / lm_head — output features
    sharded) divides N; ``kind="rep"`` leaves the weight whole. Data-parallel
    axes divide the activation rows M for every kind. A dim that the mesh
    doesn't divide stays global — mirroring ``runtime/sharding.py``, which
    only shards divisible dims.

    Dispatch decisions (Split-K degree, tiles, memory round-trips) must be
    costed on THESE shapes: row-parallel sharding moves each rank's GEMM
    deeper into the K ≫ N decode regime the paper's Split-K analysis targets,
    and a plan chosen for the global shape systematically under-splits.
    """
    if mesh is None:
        return problem
    model = _mesh_axis_size(mesh, "model")
    M, N, K = problem.M, problem.N, problem.K
    # greedy per-axis batch division, EXACTLY mirroring sharding.batch_spec:
    # a batch divisible by "pod" but not pod*data still shards (and shrinks)
    # over pod alone
    dp = 1
    for a in ("pod", "data"):
        sz = _mesh_axis_size(mesh, a)
        if sz > 1 and M % (dp * sz) == 0:
            dp *= sz
    M //= dp
    if model > 1:
        if kind == "col" and N % model == 0:
            N //= model
        elif kind == "row" and K % model == 0:
            K //= model
    return dataclasses.replace(problem, M=max(M, 1), N=N, K=K)


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """A dispatch decision: which strategy, how to split K, which tiles.

    ``out_dtype`` of None means "the activation dtype at execute time".
    JSON round-trips exactly (see to_json/from_json).
    """

    strategy: str
    split_k: int = 1
    block_m: int = 128
    block_n: int = 256
    block_k: int = 512
    out_dtype: Optional[str] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "KernelPlan":
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "KernelPlan":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Strategy:
    """A pluggable execution strategy.

    execute(x2, qt, plan, interpret=None) -> (M, N) array, x2 always 2-D.
    cost(problem, plan) -> estimated seconds (planner ranking).
    supports(problem) -> shape/dtype eligibility gate.
    formats -> fnmatch patterns over QuantFormat names this strategy can
    execute (e.g. ``("w4a16_*",)`` covers every group size / asym variant).
    """

    name: str
    execute: Callable[..., jax.Array]
    cost: Callable[[MatmulProblem, KernelPlan], float]
    supports: Callable[[MatmulProblem], bool]
    formats: Tuple[str, ...] = ("w4a16_*",)
    splittable: bool = False    # honors plan.split_k / tile refinement
                                # (the tiled Pallas kernels; XLA paths don't)

    def supports_format(self, format_name: str) -> bool:
        return any(fnmatch.fnmatchcase(format_name, pat)
                   for pat in self.formats)


_REGISTRY: Dict[str, Strategy] = {}


def register_strategy(name: str, *, cost=None, supports=None,
                      formats: Tuple[str, ...] = ("w4a16_*",),
                      splittable: bool = False):
    """Register an execute fn under ``name``; the planner picks it up with
    no dispatcher edits. ``cost`` defaults to +inf (never auto-chosen,
    still explicitly runnable); ``supports`` defaults to always-eligible;
    ``formats`` defaults to the W4A16 family — a strategy for another
    precision declares its own patterns (e.g. ``formats=("w4a8_*",)``).
    ``splittable=True`` tells the planner the strategy honors
    ``plan.split_k`` and tile refinement (the tiled Pallas kernels)."""

    def deco(fn):
        _REGISTRY[name] = Strategy(
            name=name,
            execute=fn,
            cost=cost or (lambda problem, plan: float("inf")),
            supports=supports or (lambda problem: True),
            formats=tuple(formats),
            splittable=splittable,
        )
        return fn

    return deco


def get_strategy(name: str) -> Strategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {available_strategies()}"
        ) from None


def available_strategies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def strategies_for_format(format_name: str) -> Tuple[str, ...]:
    """Names of registered strategies that can execute ``format_name``."""
    return tuple(s.name for s in _REGISTRY.values()
                 if s.supports_format(format_name))


# ---------------------------------------------------------------------------
# Split-K heuristic (paper Fig. 2) and core counting
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def num_cores() -> int:
    """Parallel-unit count for the occupancy heuristic: on TPU, the local
    chips × 2 TensorCores (megacore); elsewhere the paper-model default of
    8 — a CPU host is modeling the target chip, not itself."""
    try:
        dev = jax.local_devices()[0]
        if dev.platform == "tpu":
            return max(1, jax.local_device_count() * 2)
    except Exception:  # pragma: no cover - no devices during docs builds
        pass
    return 8


def choose_split_k(M: int, N: int, K: int, *, group_size: int = 128,
                   block_m: int = 128, block_n: int = 256) -> int:
    """Paper-informed Split-K heuristic: split when output tiles underfill
    the chip and K is deep (K ≫ N — decode GEMMs)."""
    if group_size <= 0 or K % group_size:
        return 1          # K-slices could not stay group-aligned
    cores = num_cores()
    m_tiles = max(1, -(-M // block_m))
    n_tiles = max(1, -(-N // block_n))
    tiles = m_tiles * n_tiles
    if tiles >= cores or K < 2 * group_size:
        return 1
    want = min(cores // tiles, K // group_size)
    s = 1
    while s * 2 <= want and K % (s * 2) == 0 and (K // (s * 2)) % group_size == 0:
        s *= 2
    return s


# ---------------------------------------------------------------------------
# Cost models (seconds; lower wins). Pallas strategies pay a large factor
# off-TPU: interpret mode executes the grid as a Python loop, so the
# planner must never auto-pick them on a CPU host.
# ---------------------------------------------------------------------------

_INTERPRET_PENALTY = 1e4


def _pallas_factor(problem: MatmulProblem) -> float:
    return 1.0 if problem.backend == "tpu" else _INTERPRET_PENALTY


def _cost_fused(problem: MatmulProblem, plan: KernelPlan) -> float:
    return (costmodel.w4a16_time_tpu_fused(problem.M, problem.N, problem.K)
            * problem.batch * _pallas_factor(problem))


def _cost_decoupled(problem: MatmulProblem, plan: KernelPlan) -> float:
    return (costmodel.w4a16_time_tpu_decoupled(
        problem.M, problem.N, problem.K, split_k=max(plan.split_k, 1))
        * problem.batch * _pallas_factor(problem))


def _cost_xla(problem: MatmulProblem, plan: KernelPlan) -> float:
    """Dequant materialized once by XLA (int4 read + float write) + GEMM."""
    M, N, K = problem.M, problem.N, problem.K
    spec = costmodel.TPU_V5E
    t_deq = (0.5 * K * N + 2 * K * N) / spec.hbm_bw
    t_mm = max((2 * M * N * K) / spec.flops,
               (2 * M * K + 2 * K * N + 2 * M * N) / spec.hbm_bw)
    return (t_deq + t_mm) * problem.batch


def _cost_reference(problem: MatmulProblem, plan: KernelPlan) -> float:
    # same math as "xla" but without the loop-invariance barrier — XLA may
    # hoist the dequant and re-materialize the model in bf16; keep it as a
    # correctness oracle, never the planner's pick
    return _cost_xla(problem, plan) * 1.25


def _supports_pallas(problem: MatmulProblem) -> bool:
    # the kernels pad M and re-pick blocks, but K must be packable/grouped
    return (problem.group_size > 0 and problem.K % 2 == 0
            and problem.K % problem.group_size == 0)


def _cost_w4a8(problem: MatmulProblem, plan: KernelPlan) -> float:
    """W4A8 reference path: int8 activation read (half the fp16 bytes),
    packed int4 weight read, int32 MACs at MXU rate — plus the (M, G, N)
    fp32 group-accumulator the XLA einsum formulation materializes, which
    is exactly what the fused Pallas kernel avoids."""
    M, N, K = problem.M, problem.N, problem.K
    spec = costmodel.TPU_V5E
    g = max(problem.group_size, 1)
    bytes_moved = (M * K + 0.5 * K * N + 4.0 * K * N / g + 2 * M * N
                   + 8.0 * M * N * (K // g))        # write + read the acc
    t = max((2 * M * N * K) / spec.flops, bytes_moved / spec.hbm_bw)
    return t * problem.batch


def _cost_w8a16_fused(problem: MatmulProblem, plan: KernelPlan) -> float:
    return (costmodel.w8a16_time_tpu_fused(problem.M, problem.N, problem.K)
            * problem.batch * _pallas_factor(problem))


def _cost_w4a8_fused(problem: MatmulProblem, plan: KernelPlan) -> float:
    return (costmodel.w4a8_time_tpu_fused(
        problem.M, problem.N, problem.K, group=problem.group_size)
        * problem.batch * _pallas_factor(problem))


# ---------------------------------------------------------------------------
# Registered strategies. "decoupled" (the paper-faithful pipeline) plugs in
# through the same decorator as everything else — the acceptance demo that
# a strategy needs no dispatcher edits.
# ---------------------------------------------------------------------------

def _exec_out_dtype(plan: KernelPlan, x: jax.Array):
    return jnp.dtype(plan.out_dtype) if plan.out_dtype else x.dtype


_FLOAT_ACT_FORMATS = ("w4a16_*", "w8a16_*")   # anything dequantize handles


@register_strategy("reference", cost=_cost_reference,
                   formats=_FLOAT_ACT_FORMATS)
def _run_reference(x2, qt, plan, *, interpret=None):
    return ref.w4a16_ref(x2, qt, out_dtype=_exec_out_dtype(plan, x2))


def _pinned_qt(qt: QuantizedTensor) -> QuantizedTensor:
    """qt behind an optimization barrier: pins dequantization INSIDE the
    enclosing (layer) loop. Without it XLA's loop-invariant code motion
    hoists Dequant(W) for every scanned layer out of the decode loop and
    materializes the whole model in bf16 — silently undoing the 4× (or 2×)
    quantized-weight memory win."""
    pinned = jax.lax.optimization_barrier(
        (qt.packed, qt.scales) + (() if qt.zeros is None else (qt.zeros,)))
    zeros = pinned[2] if qt.zeros is not None else None
    return QuantizedTensor(pinned[0], pinned[1], zeros,
                           qt.group_size, qt.out_dtype, qt.format)


@register_strategy("xla", cost=_cost_xla, formats=_FLOAT_ACT_FORMATS)
def _run_xla(x2, qt, plan, *, interpret=None):
    w = dequantize(_pinned_qt(qt))
    return jnp.dot(
        x2.astype(w.dtype), w, preferred_element_type=jnp.float32
    ).astype(_exec_out_dtype(plan, x2))


@register_strategy("w4a8_xla", cost=_cost_w4a8, supports=_supports_pallas,
                   formats=("w4a8_*",))
def _run_w4a8_xla(x2, qt, plan, *, interpret=None):
    # dynamic per-token int8 activations × int4 weights, int32 group
    # accumulation (LiquidGEMM-style); barrier for the same reason as "xla"
    return w4a8_matmul_ref(x2, _pinned_qt(qt)).astype(
        _exec_out_dtype(plan, x2))


@register_strategy("fused", cost=_cost_fused, supports=_supports_pallas,
                   splittable=True)
def _run_fused(x2, qt, plan, *, interpret=None):
    return w4a16_fused(
        x2, qt, split_k=max(plan.split_k, 1),
        block_m=plan.block_m, block_n=plan.block_n, block_k=plan.block_k,
        out_dtype=_exec_out_dtype(plan, x2), interpret=interpret)


@register_strategy("decoupled", cost=_cost_decoupled,
                   supports=_supports_pallas, splittable=True)
def _run_decoupled(x2, qt, plan, *, interpret=None):
    return w4a16_decoupled(
        x2, qt, split_k=max(plan.split_k, 1),
        block_m=plan.block_m, block_n=plan.block_n, block_k=plan.block_k,
        out_dtype=_exec_out_dtype(plan, x2), interpret=interpret)


def _supports_w8a16_pallas(problem: MatmulProblem) -> bool:
    # per-channel (or per-tensor) scales: one scale row spans all of K;
    # int8 rows have no packing constraint on K
    return problem.group_size >= problem.K > 0


@register_strategy("w8a16_fused", cost=_cost_w8a16_fused,
                   supports=_supports_w8a16_pallas,
                   formats=("w8a16_channel*",), splittable=True)
def _run_w8a16_fused(x2, qt, plan, *, interpret=None):
    return w8a16_fused(
        x2, qt, split_k=max(plan.split_k, 1),
        block_m=plan.block_m, block_n=plan.block_n, block_k=plan.block_k,
        out_dtype=_exec_out_dtype(plan, x2), interpret=interpret)


@register_strategy("w4a8_fused", cost=_cost_w4a8_fused,
                   supports=_supports_pallas, formats=("w4a8_*",),
                   splittable=True)
def _run_w4a8_fused(x2, qt, plan, *, interpret=None):
    return w4a8_fused(
        x2, qt, split_k=max(plan.split_k, 1),
        block_m=plan.block_m, block_n=plan.block_n, block_k=plan.block_k,
        out_dtype=_exec_out_dtype(plan, x2), interpret=interpret)


# ---------------------------------------------------------------------------
# Plan cache (process-wide, JSON-persistent)
# ---------------------------------------------------------------------------

class PlanCache:
    """Problem → plan memo with hit/miss stats and JSON persistence.

    Only planner-chosen (strategy-unforced) plans are cached; forced or
    overridden plans are cheap to rebuild and would poison lookups.
    """

    _VERSION = 1

    def __init__(self) -> None:
        self._plans: Dict[MatmulProblem, KernelPlan] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._plans)

    def get(self, problem: MatmulProblem) -> Optional[KernelPlan]:
        with self._lock:
            plan = self._plans.get(problem)
            if plan is None:
                self.misses += 1
            else:
                self.hits += 1
            return plan

    def put(self, problem: MatmulProblem, plan: KernelPlan) -> None:
        with self._lock:
            self._plans[problem] = plan

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self.hits = self.misses = 0

    def save(self, path: str) -> int:
        """Persist every cached decision; returns the entry count.

        The write is atomic (tmp file + ``os.replace``): a crash mid-save
        can never truncate a shared plan-cache file that other runs
        warm-start from — they see either the old or the new contents.
        """
        with self._lock:
            entries = [{"problem": prob.to_dict(), "plan": plan.to_dict()}
                       for prob, plan in self._plans.items()]
        blob = json.dumps({"version": self._VERSION, "plans": entries},
                          indent=1, sort_keys=True)
        d = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(
            dir=d, prefix=os.path.basename(path) + ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(blob)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return len(entries)

    def load(self, path: str, *, merge: bool = True) -> int:
        """Load persisted decisions (merging over the current contents by
        default); returns the number of entries loaded. Any malformed
        content raises ValueError (never TypeError/AttributeError), so
        callers can guard with one exception type."""
        with open(path) as f:
            blob = json.load(f)      # JSONDecodeError is a ValueError
        try:
            if blob.get("version") != self._VERSION:
                raise ValueError(
                    f"unsupported plan-cache version in {path}: "
                    f"{blob.get('version')!r}")
            loaded = {MatmulProblem.from_dict(e["problem"]):
                      KernelPlan.from_dict(e["plan"]) for e in blob["plans"]}
        except (TypeError, AttributeError, KeyError) as e:
            raise ValueError(f"malformed plan cache {path}: {e}") from e
        # a cache written by a build with extra strategies must not smuggle
        # un-executable plans past tolerant loading: keep only entries this
        # process can actually dispatch
        loaded = {prob: plan for prob, plan in loaded.items()
                  if plan.strategy in _REGISTRY}
        with self._lock:
            if not merge:
                self._plans.clear()
            self._plans.update(loaded)
        return len(loaded)


PLAN_CACHE = PlanCache()


def load_plan_cache(path: str, *, merge: bool = True,
                    tolerant: bool = False) -> int:
    """Load ``path`` into the process cache. With ``tolerant=True`` a
    missing or unreadable file is a no-op returning -1 — launchers warm-
    starting from an optional cache must never die on a stale file."""
    try:
        return PLAN_CACHE.load(path, merge=merge)
    except (OSError, ValueError):
        if tolerant:
            return -1
        raise


def save_plan_cache(path: str) -> int:
    return PLAN_CACHE.save(path)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def _default_plan(problem: MatmulProblem, strategy: str,
                  refine: bool) -> KernelPlan:
    """Heuristic (or refined) plan parameters for one strategy."""
    split_k = 1
    block_m, block_n, block_k = 128, 256, 512
    if get_strategy(strategy).splittable:
        split_k = choose_split_k(problem.M, problem.N, problem.K,
                                 group_size=problem.group_size)
        if refine:
            # the former autotune.py search, now the planner's optional
            # measurement/refinement pass: rank tile candidates under the
            # VMEM budget with the v5e roofline
            from repro.kernels.autotune import autotune_w4a16

            block_m, block_n, block_k, split_k = autotune_w4a16(
                problem.M, problem.N, problem.K, group=problem.group_size)
    return KernelPlan(strategy=strategy, split_k=split_k, block_m=block_m,
                      block_n=block_n, block_k=block_k,
                      out_dtype=problem.out_dtype)


def plan_matmul(problem: MatmulProblem, *, strategy: Optional[str] = None,
                refine: bool = False, use_cache: bool = True,
                cache: Optional[PlanCache] = None) -> KernelPlan:
    """Choose a :class:`KernelPlan` for ``problem``.

    With ``strategy=None`` every registered strategy that supports the
    problem's quantization format (and shape) is ranked by its cost model
    and the cheapest wins; the decision is memoized in the plan cache
    (process-wide, JSON-persistable). A named ``strategy`` forces the
    choice — but a strategy/format pair the strategy doesn't declare
    support for is refused with a ValueError, not silently mis-executed.
    ``refine=True`` additionally runs the tile-search refinement
    (ex-autotune) for Pallas strategies.
    """
    if strategy is not None:
        strat = get_strategy(strategy)
        if not strat.supports_format(problem.format):
            eligible = list(strategies_for_format(problem.format)) or (
                "none — register one with "
                "@register_strategy(..., formats=...)")
            raise ValueError(
                f"strategy {strat.name!r} does not support quantization "
                f"format {problem.format!r} (it supports formats matching "
                f"{list(strat.formats)}); strategies that do: {eligible}")
        return _default_plan(problem, strat.name, refine)

    cache = cache if cache is not None else PLAN_CACHE
    if use_cache and not refine:
        # a refine request must reach the tile search even when a heuristic
        # plan is already cached; the refined plan then overwrites it
        hit = cache.get(problem)
        if hit is not None:
            return hit

    best: Optional[Tuple[float, int, KernelPlan]] = None
    for order, strat in enumerate(_REGISTRY.values()):
        if not strat.supports_format(problem.format) \
                or not strat.supports(problem):
            continue
        plan = _default_plan(problem, strat.name, refine)
        score = strat.cost(problem, plan)
        if best is None or (score, order) < (best[0], best[1]):
            best = (score, order, plan)
    if best is None:
        # the W4A16 family always has the unconditional "reference" oracle,
        # so reaching here means every strategy for this format rejected
        # the shape (or none exists) — refuse loudly rather than return a
        # plan that would crash at execute time
        candidates = strategies_for_format(problem.format)
        if candidates:
            raise ValueError(
                f"no strategy supporting format {problem.format!r} can "
                f"execute this problem shape (M={problem.M}, N={problem.N}, "
                f"K={problem.K}, group_size={problem.group_size}); "
                f"{list(candidates)} rejected it — for packed-int4 formats "
                f"K must be even and divisible by the group size")
        raise ValueError(
            f"no registered strategy supports quantization format "
            f"{problem.format!r} (strategies: "
            f"{list(available_strategies())}); register one with "
            f"@register_strategy(..., formats=({problem.format!r},))")
    plan = best[2]
    if use_cache:
        cache.put(problem, plan)
    return plan


def resolve_plan(problem: MatmulProblem, cfg=None) -> KernelPlan:
    """Plan for a model-layer matmul, honoring config overrides.

    ``cfg.w4a16_plan`` may be a :class:`KernelPlan` (applies to every
    quantized layer), a mapping from layer key ``"KxN"`` to a plan/dict
    (per-layer override), or None. Otherwise ``cfg.w4a16_strategy`` forces
    the strategy ("auto" defers fully to the planner).
    """
    override = getattr(cfg, "w4a16_plan", None) if cfg is not None else None
    if override is not None:
        if isinstance(override, KernelPlan):
            return override
        if isinstance(override, Mapping):
            hit = override.get(problem.layer_key)
            if hit is not None:
                return hit if isinstance(hit, KernelPlan) \
                    else KernelPlan.from_dict(hit)
        elif isinstance(override, str):
            return KernelPlan.from_json(override)
    strategy = getattr(cfg, "w4a16_strategy", "auto") if cfg is not None \
        else "auto"
    if strategy and strategy != "auto":
        return plan_matmul(problem, strategy=strategy)
    return plan_matmul(problem)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def execute(plan: KernelPlan, x: jax.Array, qt: QuantizedTensor, *,
            interpret=None) -> jax.Array:
    """Run a planned quantized matmul: x (..., K) → (..., N)."""
    strat = get_strategy(plan.strategy)
    if not strat.supports_format(qt.format.name):
        raise ValueError(
            f"plan strategy {plan.strategy!r} cannot execute a "
            f"{qt.format.name!r} tensor (it supports formats matching "
            f"{list(strat.formats)}); re-plan with a problem built via "
            f"MatmulProblem.from_operands, or force one of "
            f"{list(strategies_for_format(qt.format.name))}")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = strat.execute(x2, qt, plan, interpret=interpret)
    return out.reshape(*lead, qt.N)


def matmul(x: jax.Array, qt: QuantizedTensor, *, cfg=None,
           interpret=None) -> jax.Array:
    """One-call convenience over the primary path (plan cache included)."""
    problem = MatmulProblem.from_operands(x, qt)
    return execute(resolve_plan(problem, cfg), x, qt, interpret=interpret)


def plan_for_params(params, M: int, *, refine: bool = False,
                    backend: Optional[str] = None,
                    mesh=None) -> Dict[str, KernelPlan]:
    """Pre-plan every quantized layer GEMM in a param pytree for ``M`` rows.

    Returns ``{layer_key ("KxN"): plan}``; every decision lands in the
    process plan cache, so subsequent layer-time lookups (same M/dtypes)
    are hits. ``refine=True`` runs the tile-search refinement per layer —
    the launcher-facing replacement for the old per-call autotune kwarg.

    With ``mesh`` given the planner goes SHARD-LOCAL: each leaf's TP kind
    (col/row/rep, the same name rules ``runtime/sharding.py`` shards it by)
    derives the per-rank local GEMM via :func:`shard_problem`, the plan is
    chosen by costing that local shape, and the local problem is what lands
    in the plan cache — plan-cache keys carry the shape each rank actually
    executes. The returned dict stays keyed by the GLOBAL layer_key, which
    is what trace-time ``resolve_plan`` lookups (global shapes under GSPMD)
    see, so the dict plugs straight into ``cfg.w4a16_plan``.

    A global "KxN" key can be shared by leaves of DIFFERENT TP kinds
    (square attention projections: wq is col-parallel, wo row-parallel) —
    trace-time lookups can't tell them apart, so when their shard-local
    plans disagree the key is dropped from the returned dict (those layers
    fall back to global-shape planning) rather than handing one layer the
    other's wrong-shape plan.
    """
    if mesh is not None:
        # runtime.sharding owns the name→TP-kind rules; imported lazily so
        # the kernels layer has no import-time dependency on runtime/
        from repro.runtime.sharding import leaf_kind_for_path
    plans: Dict[str, KernelPlan] = {}
    ambiguous = set()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda t: isinstance(t, QuantizedTensor))
    for path, leaf in flat:
        if not isinstance(leaf, QuantizedTensor):
            continue
        K = int(leaf.K)
        N = int(leaf.N)
        # batch=1, matching the layer-time lookup key: stacked (L, ...)
        # kernels execute as 2-D slices inside scan, so from_operands
        # builds batch=1 problems there — and batch scales every cost
        # uniformly, so the decision is stack-size-invariant anyway
        problem = MatmulProblem(
            M=int(M), N=N, K=K, group_size=leaf.group_size,
            act_dtype=str(jnp.dtype(leaf.out_dtype)),
            out_dtype=str(jnp.dtype(leaf.out_dtype)),
            has_zeros=leaf.zeros is not None,
            backend=backend or jax.default_backend(),
            format=leaf.format.name)
        if mesh is not None:
            local = shard_problem(problem, mesh, leaf_kind_for_path(path))
            plan = plan_matmul(local, refine=refine)
        else:
            plan = plan_matmul(problem, refine=refine)
        key = problem.layer_key
        if plans.get(key, plan) != plan:
            ambiguous.add(key)
        plans[key] = plan
    for key in ambiguous:
        del plans[key]
    return plans


# ---------------------------------------------------------------------------
# Decode-attention planning: ring vs gather vs fused-paged.
#
# The same decision structure as plan_matmul, transposed onto the KV cache:
# each path is a registered entry with a roofline cost
# (costmodel.attn_decode_time_tpu) and a supports() predicate, Pallas paths
# pay the interpret penalty off-TPU, and a forced path that can't serve the
# problem is refused loudly. Execution routing lives with the cache
# (runtime/kvcache.py:paged_decode_attention), not here — the planner only
# names the path, so kernels/ stays import-independent of runtime/.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionProblem:
    """One paged-attention step: B rows of ``q_len`` query tokens each
    against a ctx-token cached window, Hq query heads over Hkv KV heads
    of dim D. ``q_len`` distinguishes the three serving regimes the fused
    kernel covers — decode (1), speculative verify (k+1) and chunked
    prefill (the chunk size) — and shifts the gather/fused tradeoff: the
    gather path re-materializes the whole window per step regardless of
    q_len, so its amortized cost collapses as q_len grows only for the
    fused path."""
    B: int
    Hq: int
    Hkv: int
    D: int
    cache_len: int
    page_size: int = 16
    window: int = 0
    kv_format: str = DEFAULT_KV_FORMAT
    paged: bool = True
    backend: str = "cpu"
    act_bytes: int = 2
    q_len: int = 1

    @property
    def ctx(self) -> int:
        return self.window or self.cache_len

    @property
    def pages(self) -> int:
        return max(1, -(-self.cache_len // max(self.page_size, 1)))


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    path: str                     # "ring" | "gather" | "fused"
    kv_partitions: int = 1        # Split-K degree over the page axis


@dataclasses.dataclass(frozen=True)
class AttnPath:
    name: str
    cost: Callable[["AttentionProblem", "AttentionPlan"], float]
    supports: Callable[["AttentionProblem"], bool]


_ATTN_REGISTRY: Dict[str, AttnPath] = {}


def register_attn_path(name: str, *, cost, supports=None):
    _ATTN_REGISTRY[name] = AttnPath(
        name=name, cost=cost, supports=supports or (lambda p: True))


def available_attn_paths() -> Tuple[str, ...]:
    return tuple(_ATTN_REGISTRY)


def choose_kv_partitions(B: int, Hkv: int, pages: int, *,
                         q_tiles: int = 1) -> int:
    """Split-K over the page axis: decode attention runs at B·Hkv grid
    tiles, which underfills the chip exactly like the paper's K ≫ N GEMMs
    (Fig. 2) — partition the table until the cores fill, staying on a
    power-of-2 divisor of the table length so partitions tile evenly.
    ``q_tiles`` is the multi-query kernel's Q-tile grid axis (1 for
    decode): a chunk already fans out over B·Hkv·q_tiles tiles, so it
    needs proportionally less page-axis splitting to fill the chip."""
    cores = num_cores()
    tiles = max(1, B * Hkv * max(1, q_tiles))
    if tiles >= cores or pages < 2:
        return 1
    want = min(cores // tiles, pages)
    s = 1
    while s * 2 <= want and pages % (s * 2) == 0:
        s *= 2
    return s


def choose_q_block(q_len: int, group: int, *, target: int = 128) -> int:
    """Queries per Q-tile for the multi-query fused attention grid: the
    largest divisor Tq of ``q_len`` with Tq·group rows ≤ ``target`` (the
    sublane budget the q block and the (m, l, acc) scratch share). Decode
    (q_len=1) degenerates to Tq=1; a C=32 chunk at GQA group 4 tiles as
    one 128-row block."""
    cap = max(1, target // max(1, group))
    t = max(1, min(q_len, cap))
    while q_len % t:
        t -= 1
    return t


def _attn_quantized(problem: AttentionProblem) -> bool:
    return get_kv_format(problem.kv_format).quantized


def _attn_pallas_factor(problem: AttentionProblem) -> float:
    return 1.0 if problem.backend == "tpu" else _INTERPRET_PENALTY


def _cost_attn_ring(problem: AttentionProblem, plan: AttentionPlan) -> float:
    return costmodel.attn_decode_time_tpu(
        "ring", problem.B, problem.Hq, problem.Hkv, problem.D, problem.ctx,
        quantized=False, act_bytes=problem.act_bytes,
        q_len=problem.q_len)


def _cost_attn_gather(problem: AttentionProblem,
                      plan: AttentionPlan) -> float:
    return costmodel.attn_decode_time_tpu(
        "gather", problem.B, problem.Hq, problem.Hkv, problem.D,
        problem.ctx, quantized=_attn_quantized(problem),
        act_bytes=problem.act_bytes, q_len=problem.q_len)


def _cost_attn_fused(problem: AttentionProblem,
                     plan: AttentionPlan) -> float:
    return costmodel.attn_decode_time_tpu(
        "fused", problem.B, problem.Hq, problem.Hkv, problem.D,
        problem.ctx, quantized=_attn_quantized(problem),
        act_bytes=problem.act_bytes, q_len=problem.q_len,
        kv_partitions=plan.kv_partitions) * _attn_pallas_factor(problem)


register_attn_path("ring", cost=_cost_attn_ring,
                   supports=lambda p: not p.paged)
register_attn_path("gather", cost=_cost_attn_gather,
                   supports=lambda p: p.paged)
register_attn_path("fused", cost=_cost_attn_fused,
                   supports=lambda p: p.paged)


def _attn_plan_for(problem: AttentionProblem, name: str) -> AttentionPlan:
    parts = 1
    if name == "fused":
        group = max(1, problem.Hq // max(1, problem.Hkv))
        q_tiles = problem.q_len // choose_q_block(problem.q_len, group)
        parts = choose_kv_partitions(problem.B, problem.Hkv, problem.pages,
                                     q_tiles=q_tiles)
        # every partition flushes O(q_len·Hq·D) unnormalized partials, so
        # Split-K traffic grows with S·q_len while the window it splits is
        # fixed at ctx tokens — cap S where the combine bytes would start
        # rivaling the gather staging the fused path exists to delete
        # (binds only for multi-query tiles over short contexts; decode's
        # q_len=1 never hits it)
        while parts > 1 and parts * problem.q_len * 2 > problem.ctx:
            parts //= 2
    return AttentionPlan(path=name, kv_partitions=parts)


def plan_attention(problem: AttentionProblem, *,
                   path: Optional[str] = None) -> AttentionPlan:
    """Choose the decode-attention path for ``problem``.

    With ``path=None`` every registered path that supports the problem is
    ranked by its roofline cost and the cheapest wins — on TPU that is the
    fused kernel for paged long-context decode (one trip over the KV pool);
    on CPU hosts the interpret penalty keeps the XLA gather in front. A
    named ``path`` forces the choice but is validated against supports()
    so e.g. "ring" on a paged engine fails loudly.
    """
    if path is not None:
        if path == "auto":
            return plan_attention(problem)
        entry = _ATTN_REGISTRY.get(path)
        if entry is None:
            raise ValueError(
                f"unknown attention path {path!r} (registered: "
                f"{list(available_attn_paths())})")
        if not entry.supports(problem):
            eligible = [e.name for e in _ATTN_REGISTRY.values()
                        if e.supports(problem)]
            raise ValueError(
                f"attention path {path!r} does not support this problem "
                f"(paged={problem.paged}); paths that do: {eligible}")
        return _attn_plan_for(problem, path)

    best: Optional[Tuple[float, int, AttentionPlan]] = None
    for order, entry in enumerate(_ATTN_REGISTRY.values()):
        if not entry.supports(problem):
            continue
        plan = _attn_plan_for(problem, entry.name)
        score = entry.cost(problem, plan)
        if best is None or (score, order) < (best[0], best[1]):
            best = (score, order, plan)
    if best is None:
        raise ValueError(
            f"no registered attention path supports this problem "
            f"(paged={problem.paged}; registered: "
            f"{list(available_attn_paths())})")
    return best[2]
