"""Fused W4A16 GEMM — the TPU-native (beyond-paper) kernel.

On Ascend the dequantized weights must round-trip through global memory
because the vector cores (type-cast) and cube cores (MMAD) are decoupled.
A TPU core has its VPU and MXU on the *same* core sharing VMEM, so here the
INT4→float dequant happens in VMEM between the HBM→VMEM weight copy and the
MXU contraction: weight HBM traffic is the packed K·N/2 bytes, and the
paper's extra round-trip disappears entirely.

Two launch shapes:
  split_k == 1 : grid (M/bm, N/bn, K/bk), fp32 VMEM accumulator, direct out.
                 (the "data-parallel" strategy of the paper)
  split_k == S : grid (S, M/bm, N/bn, K/S/bk) writing S fp32 partials, then
                 an XLA sum over S. (the paper's Split-K strategy; on TPU the
                 S axis is marked "parallel" so megacore/futures overlap it)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QuantizedTensor
from repro.kernels import common


def _make_kernel(repeat: int, has_zeros: bool, partial_out: bool, k_axis: int):
    def kernel(x_ref, p_ref, s_ref, *rest):
        if has_zeros:
            z_ref, o_ref, acc_ref = rest
        else:
            z_ref = None
            o_ref, acc_ref = rest
        k = pl.program_id(k_axis)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        w = common.dequant_block(p_ref, s_ref, z_ref, repeat, x_ref.dtype)
        acc_ref[...] += jnp.dot(
            x_ref[...], w, preferred_element_type=jnp.float32
        )

        @pl.when(k == pl.num_programs(k_axis) - 1)
        def _flush():
            if partial_out:
                o_ref[0] = acc_ref[...].astype(o_ref.dtype)
            else:
                o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    return kernel


def _choose_blocks(M, N, K, group_size, block_m, block_n, block_k, split_k):
    bm = common.largest_divisor(M, block_m)
    bn = common.pick_block(N, block_n)
    ks = K // split_k
    # bk must divide the K-slice and be group-compatible (bk % g or g % bk)
    bk = common.pick_block(ks, block_k)
    while bk > 1 and not (bk % group_size == 0 or group_size % bk == 0):
        bk = common.largest_divisor(ks, bk - 1)
    return bm, bn, bk


@functools.partial(
    jax.jit,
    static_argnames=(
        "split_k", "block_m", "block_n", "block_k", "out_dtype", "interpret",
    ),
)
def w4a16_fused(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    split_k: int = 1,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """C = x · Dequant(W), dequantizing in VMEM. x:(M,K) float, W packed."""
    out_dtype = out_dtype or x.dtype
    interpret = common.resolve_interpret(interpret)
    M, K = x.shape
    assert K == qt.K, (x.shape, qt.shape)
    N = qt.N
    g = qt.group_size
    assert K % split_k == 0 and (K // split_k) % g == 0, (
        f"K={K} split_k={split_k} must keep K-slices group-aligned (g={g})"
    )

    x = common.pad_dim(x, 0, common.SUBLANE)
    Mp = x.shape[0]
    bm, bn, bk = _choose_blocks(Mp, N, K, g, block_m, block_n, block_k, split_k)
    repeat = min(bk, g)                      # scale rows expand by this factor
    spb = max(1, bk // g)                    # scale rows per block
    has_zeros = qt.zeros is not None
    ks = K // split_k
    nk = ks // bk

    def x_map(s, m, n, k):
        return (m, s * nk + k)

    def p_map(s, m, n, k):
        return (s * nk + k, n)

    def s_map(s, m, n, k):
        return (((s * nk + k) * bk) // g // spb, n)

    in_specs = [
        pl.BlockSpec((bm, bk), x_map),
        pl.BlockSpec((bk // 2, bn), p_map),
        pl.BlockSpec((spb, bn), s_map),
    ]
    operands = [x, qt.packed, qt.scales]
    if has_zeros:
        in_specs.append(pl.BlockSpec((spb, bn), s_map))
        operands.append(qt.zeros)

    if split_k == 1:
        # strip the s index for the direct-output launch
        def drop_s(f):
            return lambda m, n, k: f(0, m, n, k)

        in_specs = [
            pl.BlockSpec((bm, bk), drop_s(x_map)),
            pl.BlockSpec((bk // 2, bn), drop_s(p_map)),
            pl.BlockSpec((spb, bn), drop_s(s_map)),
        ]
        if has_zeros:
            in_specs.append(pl.BlockSpec((spb, bn), drop_s(s_map)))
        grid = (Mp // bm, N // bn, nk)
        out = pl.pallas_call(
            _make_kernel(repeat, has_zeros, partial_out=False, k_axis=2),
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
            out_shape=jax.ShapeDtypeStruct((Mp, N), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            compiler_params=common.compiler_params(
                ("parallel", "parallel", "arbitrary")
            ),
            interpret=interpret,
        )(*operands)
        return out[:M]

    grid = (split_k, Mp // bm, N // bn, nk)
    partials = pl.pallas_call(
        _make_kernel(repeat, has_zeros, partial_out=True, k_axis=3),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, m, n, k: (s, m, n)),
        out_shape=jax.ShapeDtypeStruct((split_k, Mp, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=common.compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return jnp.sum(partials, axis=0).astype(out_dtype)[:M]
