"""Fused W4A16 GEMM — the TPU-native (beyond-paper) kernel.

On Ascend the dequantized weights must round-trip through global memory
because the vector cores (type-cast) and cube cores (MMAD) are decoupled.
A TPU core has its VPU and MXU on the *same* core sharing VMEM, so here the
INT4→float dequant happens in VMEM between the HBM→VMEM weight copy and the
MXU contraction: weight HBM traffic is the packed K·N/2 bytes, and the
paper's extra round-trip disappears entirely.

Composed from the stage template (kernels/template.py): grouped INT4
dequant weight stage + float MXU contraction, in both of the paper's launch
shapes:

  split_k == 1 : grid (M/bm, N/bn, K/bk), fp32 VMEM accumulator, direct out.
                 (the "data-parallel" strategy of the paper)
  split_k == S : grid (S, M/bm, N/bn, K/S/bk) writing S fp32 partials, then
                 an XLA sum over S. (the paper's Split-K strategy; on TPU the
                 S axis is marked "parallel" so megacore/futures overlap it)
"""
from __future__ import annotations

import functools

import jax

from repro.core.quant import QuantizedTensor
from repro.kernels import template


@functools.partial(
    jax.jit,
    static_argnames=(
        "split_k", "block_m", "block_n", "block_k", "out_dtype", "interpret",
    ),
)
def w4a16_fused(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    split_k: int = 1,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """C = x · Dequant(W), dequantizing in VMEM. x:(M,K) float, W packed."""
    K = x.shape[1]
    assert K == qt.K, (x.shape, qt.shape)
    return template.tiled_matmul(
        x,
        template.GroupedInt4Dequant(qt.packed, qt.scales, qt.zeros),
        template.FloatContraction(),
        N=qt.N,
        group_size=qt.group_size,
        split_k=split_k,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype or x.dtype,
        interpret=interpret,
    )
