"""Stage-based tiled-GEMM template — paper Alg. 1 as composable stages.

Every quantized-GEMM kernel in this package is the same three-stage loop,
and the stages map one-to-one onto the paper's Alg. 1 phases:

  weight stage  (AIV role)  — produce the (bk, bn) weight tile in VMEM:
                              identity load (:class:`DenseWeight`), grouped
                              INT4 dequant (:class:`GroupedInt4Dequant`),
                              per-channel INT8 dequant
                              (:class:`ChannelInt8Dequant`), or a raw INT4→
                              INT8 unpack feeding an integer MXU dot
                              (:class:`GroupedInt4Raw`);
  contraction   (AIC role)  — accumulate x_tile · w_tile into the fp32 VMEM
                              accumulator: a float MXU dot
                              (:class:`FloatContraction`) or an int8×int8
                              ``preferred_element_type=int32`` dot with
                              per-group rescale at the group boundary
                              (:class:`Int8GroupContraction`);
  epilogue      (AIV role)  — in-kernel flush (downcast on the last k step,
                              or a partial write per Split-K slice) plus a
                              host-side finalize (Split-K reduce, per-token
                              rescale, M-crop).

:func:`tiled_matmul` composes the stages over a shared grid/BlockSpec
builder. Block selection (:func:`choose_blocks`) is the one place the
``[m, n, k]`` block parameter of Alg. 1 is decided: divisor-aligned blocks
near the requested targets, group-compatible ``bk``, shrunk until the
working set fits ``common.VMEM_BUDGET`` via the same
``common.vmem_working_set`` model the autotuner ranks candidates with.

Both launch shapes of the paper are provided:

  split_k == 1 : grid ``(M/bm, N/bn, K/bk)``, direct output
                 (the data-parallel strategy);
  split_k == S : grid ``(S, M/bm, N/bn, K/S/bk)`` writing S fp32 partials,
                 reduced outside the kernel (the Split-K strategy; the S
                 axis is marked "parallel" so megacore/futures overlap it).

Adding a new quantization format is a weight stage (+ contraction stage if
the arithmetic changes) and a ~20-line wrapper — see docs/kernels.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

__all__ = [
    "BlockConfig", "choose_blocks", "tiled_matmul",
    "DenseWeight", "GroupedInt4Dequant", "ChannelInt8Dequant",
    "GroupedInt4Raw", "FloatContraction", "Int8GroupContraction",
    "DensePages", "Int8ChannelPages",
]


# ---------------------------------------------------------------------------
# Shared block selection (Alg. 1's [m, n, k] under the VMEM budget)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One launch decision: block shapes + Split-K split of the K loop."""

    bm: int
    bn: int
    bk: int
    split_k: int
    nk: int                 # k grid steps per K slice ((K // split_k) // bk)
    group_size: int = 0     # K rows per scale row; 0 = ungrouped/dense


def choose_blocks(
    M: int, N: int, K: int, *,
    block_m: int = 128, block_n: int = 256, block_k: int = 512,
    split_k: int = 1, group_size: int = 0,
    act_bytes: int = 2, weight_elt_bytes: float = 2.0,
    has_scales: bool = False, dequant_tile: bool = False,
    vmem_budget: int = common.VMEM_BUDGET,
) -> BlockConfig:
    """Pick (bm, bn, bk) near the targets, then enforce the VMEM budget.

    ``bm`` divides M (callers pad M to SUBLANE first), ``bn``/``bk`` prefer
    LANE-aligned divisors, ``bk`` additionally divides the K slice and stays
    group-compatible (``bk % g == 0 or g % bk == 0``). If the working set
    (``common.vmem_working_set`` with the weight stage's byte layout)
    exceeds the budget, ``bk`` shrinks first (the dequant tile dominates),
    then ``bn``.
    """
    if K % split_k:
        raise ValueError(f"split_k={split_k} must divide K={K}")
    ks = K // split_k
    if group_size > 0 and ks % group_size:
        raise ValueError(
            f"K={K} split_k={split_k} must keep K-slices group-aligned "
            f"(group_size={group_size})")
    bm = common.largest_divisor(M, block_m)
    bn = common.pick_block(N, block_n)
    bk = common.pick_block(ks, block_k)

    def group_ok(b: int) -> bool:
        return group_size <= 0 or b % group_size == 0 or group_size % b == 0

    def shrink(b: int) -> int:
        """Largest group-compatible divisor of the K slice below ``b``."""
        b = common.largest_divisor(ks, b - 1)
        while b > 1 and not group_ok(b):
            b = common.largest_divisor(ks, b - 1)
        return b

    if not group_ok(bk):
        bk = shrink(bk + 1)

    def working_set(bn_: int, bk_: int) -> int:
        return common.vmem_working_set(
            bm, bn_, bk_, group_size or K, act_bytes=act_bytes,
            weight_elt_bytes=weight_elt_bytes, has_scales=has_scales,
            dequant_tile=dequant_tile)

    while working_set(bn, bk) > vmem_budget and bk > 1:
        bk = shrink(bk)
    while working_set(bn, bk) > vmem_budget and bn > 1:
        bn = common.largest_divisor(N, bn - 1)
    return BlockConfig(bm=bm, bn=bn, bk=bk, split_k=split_k,
                       nk=ks // bk, group_size=group_size)


# ---------------------------------------------------------------------------
# Weight stages (the AIV dequant role). Each declares how its operands are
# blocked along (K, N) — a row function mapping the global k block index to
# the operand's row block — and how the in-VMEM tile is produced.
# ---------------------------------------------------------------------------

RowFn = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class DenseWeight:
    """Identity stage: a dense (K, N) weight already in a float dtype."""

    w: jax.Array

    @property
    def vmem(self):
        return dict(weight_elt_bytes=jnp.dtype(self.w.dtype).itemsize,
                    has_scales=False, dequant_tile=False)

    def operands(self) -> List[jax.Array]:
        return [self.w]

    def layout(self, bc: BlockConfig) -> List[Tuple[Tuple[int, int], RowFn]]:
        return [((bc.bk, bc.bn), lambda kk: kk)]

    def produce(self, refs: Sequence, bc: BlockConfig, compute_dtype):
        (w_ref,) = refs
        return w_ref[...]


def _group_layout(bc: BlockConfig) -> Tuple[int, int, RowFn]:
    """(repeat, scale-rows-per-block, scale row fn) for grouped scales."""
    g = bc.group_size
    repeat = min(bc.bk, g)
    spb = max(1, bc.bk // g)
    return repeat, spb, lambda kk: (kk * bc.bk) // g // spb


@dataclasses.dataclass(frozen=True)
class GroupedInt4Dequant:
    """Grouped INT4 → float dequant in VMEM (the fused-W4A16 weight stage)."""

    packed: jax.Array                 # (K//2, N) int8, two nibbles per byte
    scales: jax.Array                 # (K//g, N)
    zeros: Optional[jax.Array]        # same shape as scales, or None

    vmem = dict(weight_elt_bytes=0.5, has_scales=True, dequant_tile=True)

    def operands(self) -> List[jax.Array]:
        ops = [self.packed, self.scales]
        if self.zeros is not None:
            ops.append(self.zeros)
        return ops

    def layout(self, bc: BlockConfig) -> List[Tuple[Tuple[int, int], RowFn]]:
        _, spb, sfn = _group_layout(bc)
        specs = [((bc.bk // 2, bc.bn), lambda kk: kk),
                 ((spb, bc.bn), sfn)]
        if self.zeros is not None:
            specs.append(((spb, bc.bn), sfn))
        return specs

    def produce(self, refs: Sequence, bc: BlockConfig, compute_dtype):
        p_ref, s_ref, *z = refs
        repeat, _, _ = _group_layout(bc)
        return common.dequant_block(
            p_ref, s_ref, z[0] if z else None, repeat, compute_dtype)


@dataclasses.dataclass(frozen=True)
class ChannelInt8Dequant:
    """Per-channel INT8 → float dequant in VMEM (the w8a16 weight stage)."""

    rows: jax.Array                   # (K, N) int8
    scales: jax.Array                 # (1, N)
    zeros: Optional[jax.Array]        # (1, N) or None

    vmem = dict(weight_elt_bytes=1.0, has_scales=True, dequant_tile=True)

    def operands(self) -> List[jax.Array]:
        ops = [self.rows, self.scales]
        if self.zeros is not None:
            ops.append(self.zeros)
        return ops

    def layout(self, bc: BlockConfig) -> List[Tuple[Tuple[int, int], RowFn]]:
        specs = [((bc.bk, bc.bn), lambda kk: kk),
                 ((1, bc.bn), lambda kk: 0)]
        if self.zeros is not None:
            specs.append(((1, bc.bn), lambda kk: 0))
        return specs

    def produce(self, refs: Sequence, bc: BlockConfig, compute_dtype):
        r_ref, s_ref, *z = refs
        return common.dequant_channel_block(
            r_ref, s_ref, z[0] if z else None, compute_dtype)


@dataclasses.dataclass(frozen=True)
class GroupedInt4Raw:
    """INT4 → INT8 unpack only — scales stay symbolic for an integer dot.

    ``produce`` returns ``(wq int8 (bk, bn), scales (spb, bn), zeros|None)``
    for :class:`Int8GroupContraction`, which applies the group scales at
    the group boundary after the int32 accumulation (LiquidGEMM-style).
    """

    packed: jax.Array
    scales: jax.Array
    zeros: Optional[jax.Array]

    # int8 tile instead of a float tile; budget-wise dequant_tile=True is a
    # safe overestimate
    vmem = dict(weight_elt_bytes=0.5, has_scales=True, dequant_tile=True)

    operands = GroupedInt4Dequant.operands
    layout = GroupedInt4Dequant.layout

    def produce(self, refs: Sequence, bc: BlockConfig, compute_dtype):
        p_ref, s_ref, *z = refs
        return (common.unpack_int4_block(p_ref), s_ref,
                z[0] if z else None)


# ---------------------------------------------------------------------------
# KV stages (the stage vocabulary extended from GEMM to attention).
#
# A KVStage is the attention analogue of a WeightStage: it declares the
# paged-pool operands the fused decode kernel walks (runtime/kvcache.py
# block pools, one physical page per grid step), how each operand is
# blocked, and how the in-VMEM (page_size, D) K/V tiles are produced —
# identity load for ``kv_fp16`` pages, per-(token, head) INT8 dequant for
# ``kv8_channel`` (the same AIV dequant role the GEMM weight stages play,
# fused into the consumer instead of round-tripping through HBM).
#
# ``block_shapes`` distinguishes operand kinds by rank: 4-d blocks
# ``(1, ps, 1, D)`` are payload pools indexed ``(page, 0, head, 0)``;
# 3-d blocks ``(1, ps, 1)`` are scale pools indexed ``(page, 0, head)``.
# The emitter (kernels/paged_attention.py) turns those into block-table
# index maps over the scalar-prefetched tables.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DensePages:
    """Identity KV stage: pool pages already hold the cache dtype
    (``kv_fp16`` — no scales, no dequant)."""

    k_pool: jax.Array                 # (num_blocks, ps, Hkv, D)
    v_pool: jax.Array

    def operands(self) -> List[jax.Array]:
        return [self.k_pool, self.v_pool]

    def block_shapes(self, ps: int, D: int) -> List[Tuple[int, ...]]:
        return [(1, ps, 1, D), (1, ps, 1, D)]

    def produce(self, refs: Sequence, compute_dtype):
        k_ref, v_ref = refs
        return (k_ref[0, :, 0, :].astype(compute_dtype),
                v_ref[0, :, 0, :].astype(compute_dtype))


@dataclasses.dataclass(frozen=True)
class Int8ChannelPages:
    """Per-(token, head) INT8 KV dequant in VMEM (``kv8_channel``).

    Matches ``core/quant.kv_dequantize`` bit-for-bit: fp32 payload × fp32
    scale, cast to the cache compute dtype — the dequantized page never
    exists outside VMEM (vs. the gather path, which materializes the whole
    dequantized window to HBM before attention reads it back).
    """

    k_pool: jax.Array                 # (num_blocks, ps, Hkv, D) int8
    v_pool: jax.Array
    k_scale: jax.Array                # (num_blocks, ps, Hkv) fp32
    v_scale: jax.Array

    def operands(self) -> List[jax.Array]:
        return [self.k_pool, self.v_pool, self.k_scale, self.v_scale]

    def block_shapes(self, ps: int, D: int) -> List[Tuple[int, ...]]:
        return [(1, ps, 1, D), (1, ps, 1, D), (1, ps, 1), (1, ps, 1)]

    def produce(self, refs: Sequence, compute_dtype):
        k_ref, v_ref, ks_ref, vs_ref = refs

        def deq(p_ref, s_ref):
            q = p_ref[0, :, 0, :].astype(jnp.float32)       # (ps, D)
            s = s_ref[0, :, 0].astype(jnp.float32)          # (ps,)
            return (q * s[:, None]).astype(compute_dtype)

        return deq(k_ref, ks_ref), deq(v_ref, vs_ref)


# ---------------------------------------------------------------------------
# Contraction stages (the AIC MXU role)
# ---------------------------------------------------------------------------

class FloatContraction:
    """acc += x · w on the MXU with fp32 accumulation."""

    def step(self, x_tile, w_tile, acc_ref, bc: BlockConfig) -> None:
        acc_ref[...] += jnp.dot(
            x_tile, w_tile, preferred_element_type=jnp.float32)


class Int8GroupContraction:
    """int8×int8 MXU dot, int32 accumulate, group rescale into fp32.

    The weight stage hands over ``(wq int8, scales, zeros|None)``; each
    scale group inside the block gets its own exact int32 dot, rescaled at
    the group boundary — the W4A8 arithmetic of ``w4a8_matmul_ref`` moved
    into the k loop. The asymmetric correction uses the per-token nibble
    sum (``z · Σ x_q``), matching the oracle.
    """

    def step(self, x_tile, w_prod, acc_ref, bc: BlockConfig) -> None:
        wq, s_ref, z_ref = w_prod
        repeat, spb, _ = _group_layout(bc)
        for i in range(spb):                      # static unroll over groups
            xs = x_tile[:, i * repeat:(i + 1) * repeat]
            ws = wq[i * repeat:(i + 1) * repeat, :]
            part = jnp.dot(
                xs, ws, preferred_element_type=jnp.int32
            ).astype(jnp.float32)
            if z_ref is not None:
                tok = jnp.sum(xs.astype(jnp.int32), axis=1)
                part = part - (z_ref[i, :].astype(jnp.float32)[None, :]
                               * tok.astype(jnp.float32)[:, None])
            acc_ref[...] += part * s_ref[i, :].astype(jnp.float32)[None, :]


# ---------------------------------------------------------------------------
# The emitter
# ---------------------------------------------------------------------------

def _make_kernel(weight_stage, contraction, bc: BlockConfig, *,
                 n_weight_refs: int, partial_out: bool, k_axis: int,
                 compute_dtype):
    def kernel(x_ref, *rest):
        w_refs = rest[:n_weight_refs]
        o_ref, acc_ref = rest[n_weight_refs:]
        k = pl.program_id(k_axis)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        w_tile = weight_stage.produce(w_refs, bc, compute_dtype)
        contraction.step(x_ref[...], w_tile, acc_ref, bc)

        @pl.when(k == pl.num_programs(k_axis) - 1)
        def _flush():
            if partial_out:
                o_ref[0] = acc_ref[...].astype(o_ref.dtype)
            else:
                o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    return kernel


def tiled_matmul(
    x: jax.Array,
    weight_stage,
    contraction,
    *,
    N: int,
    group_size: int = 0,
    split_k: int = 1,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret=None,
    reduce_splits: bool = True,
    finalize: Optional[Callable[[jax.Array], jax.Array]] = None,
    vmem_budget: int = common.VMEM_BUDGET,
) -> jax.Array:
    """Emit one tiled GEMM from a (weight stage, contraction) pair.

    x : (M, K); M is padded to SUBLANE internally and cropped on return.
    With ``split_k == 1`` the kernel writes the output directly; with
    ``split_k == S`` it writes S fp32 partials which are summed outside
    (set ``reduce_splits=False`` to get the raw ``(S, M, N)`` partials —
    the decoupled pipeline reduces them in its own phase-3 kernel).
    ``finalize`` runs host-side on the fp32 result before the out_dtype
    cast (per-token rescale lives here).
    """
    out_dtype = out_dtype or x.dtype
    interpret = common.resolve_interpret(interpret)
    M, K = x.shape
    x = common.pad_dim(x, 0, common.SUBLANE)
    Mp = x.shape[0]

    bc = choose_blocks(
        Mp, N, K, block_m=block_m, block_n=block_n, block_k=block_k,
        split_k=split_k, group_size=group_size,
        act_bytes=max(1, jnp.dtype(x.dtype).itemsize),
        vmem_budget=vmem_budget, **weight_stage.vmem)
    layout = weight_stage.layout(bc)
    operands = [x] + weight_stage.operands()

    # kernel output dtype: direct out unless a host-side pass still needs
    # the fp32 accumulator (Split-K reduce and/or finalize)
    direct = split_k == 1 and finalize is None
    kernel_dtype = jnp.dtype(out_dtype) if direct else jnp.float32

    # raw-partials callers (the decoupled pipeline's phase 2) get the
    # (S, M, N) launch shape even at S == 1
    if split_k == 1 and reduce_splits:
        in_specs = [pl.BlockSpec((bc.bm, bc.bk), lambda m, n, k: (m, k))]
        for shape, row_fn in layout:
            in_specs.append(pl.BlockSpec(
                shape, lambda m, n, k, rf=row_fn: (rf(k), n)))
        out = pl.pallas_call(
            _make_kernel(weight_stage, contraction, bc,
                         n_weight_refs=len(layout), partial_out=False,
                         k_axis=2, compute_dtype=x.dtype),
            grid=(Mp // bc.bm, N // bc.bn, bc.nk),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bc.bm, bc.bn), lambda m, n, k: (m, n)),
            out_shape=jax.ShapeDtypeStruct((Mp, N), kernel_dtype),
            scratch_shapes=[pltpu.VMEM((bc.bm, bc.bn), jnp.float32)],
            compiler_params=common.compiler_params(
                ("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(*operands)
        out = out[:M]
        if finalize is not None:
            out = finalize(out)
        return out.astype(out_dtype)

    nk = bc.nk
    in_specs = [pl.BlockSpec((bc.bm, bc.bk),
                             lambda s, m, n, k: (m, s * nk + k))]
    for shape, row_fn in layout:
        in_specs.append(pl.BlockSpec(
            shape, lambda s, m, n, k, rf=row_fn: (rf(s * nk + k), n)))
    partials = pl.pallas_call(
        _make_kernel(weight_stage, contraction, bc,
                     n_weight_refs=len(layout), partial_out=True,
                     k_axis=3, compute_dtype=x.dtype),
        grid=(split_k, Mp // bc.bm, N // bc.bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc.bm, bc.bn),
                               lambda s, m, n, k: (s, m, n)),
        out_shape=jax.ShapeDtypeStruct((split_k, Mp, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bc.bm, bc.bn), jnp.float32)],
        compiler_params=common.compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    partials = partials[:, :M]
    if not reduce_splits:
        return partials
    out = jnp.sum(partials, axis=0)
    if finalize is not None:
        out = finalize(out)
    return out.astype(out_dtype)
