"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, dequantize, unpack_int4


def gemm_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    """Plain tiled-GEMM oracle: fp32 accumulation, cast to out dtype."""
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)


def dequant_ref(
    packed: jax.Array,
    scales: jax.Array,
    zeros: Optional[jax.Array],
    group_size: int,
    out_dtype=jnp.bfloat16,
) -> jax.Array:
    """Phase-1 oracle: unpack int4 + apply group scales → (K, N) out_dtype."""
    q = unpack_int4(packed).astype(jnp.float32)
    s = jnp.repeat(scales.astype(jnp.float32), group_size, axis=0)
    if zeros is not None:
        q = q - jnp.repeat(zeros.astype(jnp.float32), group_size, axis=0)
    return (q * s).astype(out_dtype)


def w4a16_ref(x: jax.Array, qt: QuantizedTensor, out_dtype=None) -> jax.Array:
    """End-to-end W4A16 oracle (paper Eq. 2): C = A · Dequant(W)."""
    out_dtype = out_dtype or x.dtype
    w = dequantize(qt)
    return jnp.dot(
        x.astype(w.dtype), w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def splitk_partials_ref(
    x: jax.Array, w: jax.Array, split_k: int
) -> jax.Array:
    """Phase-2 oracle: S partial fp32 GEMMs over K-slices (paper Alg. 1)."""
    M, K = x.shape
    _, N = w.shape
    ks = K // split_k
    parts = [
        jnp.dot(
            x[:, i * ks : (i + 1) * ks],
            w[i * ks : (i + 1) * ks, :],
            preferred_element_type=jnp.float32,
        )
        for i in range(split_k)
    ]
    return jnp.stack(parts, axis=0)  # (S, M, N) fp32


def reduce_ref(partials: jax.Array, out_dtype=jnp.bfloat16) -> jax.Array:
    """Phase-3 oracle: elementwise sum over S + downcast (paper Alg. 1)."""
    return jnp.sum(partials, axis=0).astype(out_dtype)


def attention_ref(q, k, v, *, causal=True, window=0):
    """Full-softmax GQA attention oracle. q:(B,Sq,Hq,D), k/v:(B,Skv,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
