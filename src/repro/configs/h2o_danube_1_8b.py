"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# h2o-danube-1.8b — dense, llama+mistral mix with sliding-window attention
# [arXiv:2401.16818; hf]. SWA window 4096 → O(window) decode state, so this
# arch RUNS the long_500k cell.
CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80, sliding_window=4096,
    rope_theta=10_000.0,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, sliding_window=16,
    dtype=jnp.float32, remat=False)
