"""Architecture registry + ShapeDtypeStruct input specs for every cell.

``get_config(arch)`` / ``get_reduced(arch)`` resolve ``--arch`` ids;
``input_specs(cfg, shape)`` builds the allocation-free stand-ins the
multi-pod dry-run lowers against.
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec, cache_len_for, skip_reason
from repro.models.config import ModelConfig

ARCHS = (
    "granite-20b",
    "h2o-danube-1.8b",
    "starcoder2-7b",
    "llama3-405b",
    "internvl2-1b",
    "whisper-small",
    "rwkv6-7b",
    "mixtral-8x7b",
    "olmoe-1b-7b",
    "hymba-1.5b",
)

# the paper's own benchmark GEMM shapes (Figs. 2–3): (N, K) weight dims drawn
# from OpenPangu / DeepSeek-R1 / GLM-4.5 / LLaMA-3.2 projection layers
PAPER_GEMM_SHAPES = [
    (2048, 16384), (4096, 8192), (1024, 8192), (7168, 2048),
    (2048, 7168), (4096, 4096), (8192, 4096), (5120, 13824),
]
PAPER_BATCH_SIZES = [1, 4, 16, 64, 256]


def _module(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract inputs for the (arch × shape) cell.

    train    → kwargs for train_step:  {"batch": {tokens, labels, [embeds]}}
    prefill  → kwargs for prefill_step: {"tokens", [embeds]}
    decode   → kwargs for serve_step:  {"state", "tokens", "pos"}
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    def text_len():
        return S - cfg.vision_prefix if cfg.vision_prefix else S

    if shape.kind == "train":
        batch = {
            "tokens": sds((B, text_len()), i32),
            "labels": sds((B, text_len()), i32),
        }
        if cfg.vision_prefix:
            batch["vision_embeds"] = sds(
                (B, cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            batch["audio_embeds"] = sds(
                (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        return {"batch": batch}

    if shape.kind == "prefill":
        out = {"tokens": sds((B, text_len()), i32)}
        if cfg.vision_prefix:
            out["prefix_embeds"] = sds(
                (B, cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            out["audio_embeds"] = sds(
                (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        return out

    # decode: one new token against a seq_len-deep cache
    from repro.models import transformer as T

    cache_len = cache_len_for(cfg, shape)
    state = jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, cache_len))
    return {
        "state": state,
        "tokens": sds((B,), i32),
        "pos": sds((B,), i32),
    }


__all__ = [
    "ARCHS", "SHAPES", "ShapeSpec", "PAPER_GEMM_SHAPES", "PAPER_BATCH_SIZES",
    "get_config", "get_reduced", "all_configs", "input_specs",
    "skip_reason", "cache_len_for",
]
