"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# llama3-405b — dense frontier-scale, GQA kv=8, 128k vocab
# [arXiv:2407.21783; unverified]. The FSDP + microbatching stress test.
CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128, rope_theta=500_000.0,
    bf16_partials=True,   # §Perf iter L2: TP activation collectives in bf16
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    head_dim=32, d_ff=512, vocab_size=512, dtype=jnp.float32, remat=False)
