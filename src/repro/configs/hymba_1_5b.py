"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# hymba-1.5b — hybrid: parallel attention + Mamba(SSM) heads in every layer,
# ssm_state=16, SWA on the attention half [arXiv:2411.13676; hf].
# O(window)+O(1) decode state → runs long_500k.
CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64, ssm_state=16, ssm_expand=2,
    sliding_window=1024, rope_theta=10_000.0,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, ssm_state=8, ssm_expand=2,
    sliding_window=16, dtype=jnp.float32, remat=False)
