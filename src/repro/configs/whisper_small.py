"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# whisper-small — encoder-decoder audio backbone; conv frontend is a STUB
# (input_specs supplies precomputed frame embeddings, capped at the model's
# 1500-frame positional length). LayerNorm+GELU per the original; positions
# use RoPE here (adaptation noted in DESIGN.md) so the 32k decoder shapes
# are well-defined beyond Whisper's learned 448 positions.
# [arXiv:2212.04356; unverified]
CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    encoder_layers=12, encoder_seq=1500,
    mlp_type="gelu", norm_type="layernorm", rope_theta=10_000.0,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512, encoder_layers=2, encoder_seq=32,
    dtype=jnp.float32, remat=False)
