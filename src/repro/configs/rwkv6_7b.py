"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# rwkv6-7b "Finch" — attention-free RWKV-6 with data-dependent decay
# [arXiv:2404.05892; hf]. Constant-size recurrent state → runs long_500k.
# num_heads = d_model / 64 (head_size 64).
CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536, head_dim=64,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
    head_dim=64, d_ff=256, vocab_size=512, dtype=jnp.float32, remat=False)
