"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# granite-20b — dense code LLM, llama-arch, extreme GQA (kv=1) [arXiv:2405.04324; hf]
CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128, rope_theta=10_000.0,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
    head_dim=32, d_ff=256, vocab_size=512, dtype=jnp.float32, remat=False)
