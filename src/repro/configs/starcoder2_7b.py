"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# starcoder2-7b — dense code LLM, GQA kv=4, RoPE [arXiv:2402.19173; hf]
CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152, head_dim=128, rope_theta=100_000.0,
    mlp_type="gelu",
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, dtype=jnp.float32, remat=False)
