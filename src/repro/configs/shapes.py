"""Assigned input-shape set (seq_len × global_batch) and skip rules."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch × shape) cell runs; otherwise why it is skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return ("full quadratic attention: a 500k dense KV cache per step is "
                "the sub-quadratic gate — skipped per brief (see DESIGN.md)")
    return None


def cache_len_for(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Decode KV-cache length: bounded by the sliding window when present."""
    if cfg.sliding_window > 0:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len
