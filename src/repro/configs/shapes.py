"""Assigned input-shape set (seq_len × global_batch) and skip rules."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch × shape) cell runs; otherwise why it is skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return ("full quadratic attention: a 500k dense KV cache per step is "
                "the sub-quadratic gate — skipped per brief (see DESIGN.md)")
    return None


def cache_len_for(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Decode KV-cache length: bounded by the sliding window when present."""
    if cfg.sliding_window > 0:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def serve_cache_len(cfg: ModelConfig, prompt_len: int, gen: int,
                    page_size: Optional[int] = None) -> int:
    """KV-cache length for serving ``prompt_len`` prompt + ``gen`` new tokens.

    Prefill writes ``prompt_len + vision_prefix`` entries and decode advances
    from ``pos0 = prompt_len + vision_prefix``, so the ring must hold
    ``pos0 + gen`` positions — sizing it from ``prompt_len + gen`` alone makes
    the pos-tagged ring silently overwrite the earliest context on
    vision-prefix archs. Encoder-decoder audio frames live in the separate
    ``enc_kv`` cross-attention cache and never consume decoder positions, so
    they deliberately do NOT widen the decoder cache. Sliding-window archs
    stay bounded by their window.

    With ``page_size`` the length is additionally rounded up to a page
    multiple — the paged cache's per-slot logical window (a ring larger
    than the window/total is semantically inert: pos-tag masking hides the
    extra slots). EVERY cache-sizing call site (ring or paged) must go
    through this function so the two layouts can never diverge — the PR-4
    vision-prefix bug class, closed structurally.
    """
    total = prompt_len + (cfg.vision_prefix or 0) + gen
    if cfg.sliding_window > 0:
        total = min(total, cfg.sliding_window)
    if page_size:
        total = -(-total // page_size) * page_size
    return total


def serve_num_pages(cfg: ModelConfig, prompt_len: int, gen: int, *,
                    page_size: int, max_batch: int) -> int:
    """Physical block-pool size for a paged serving engine.

    ``pages per slot × max_batch`` is the zero-sharing worst case, ``+ 1``
    for the reserved null block (block 0, permanently empty — unassigned
    table entries gather it). Prefix sharing only ever *lowers* live pages
    below this bound; the paged equivalent of :func:`serve_cache_len` and
    the single place pool capacity is derived.
    """
    per_slot = serve_cache_len(cfg, prompt_len, gen, page_size) // page_size
    return 1 + per_slot * max_batch
