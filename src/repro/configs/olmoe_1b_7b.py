"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# olmoe-1b-7b — fine-grained MoE: 64 experts top-8, tiny d_ff per expert
# [arXiv:2409.02060; hf]. Full attention → long_500k skipped.
CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    num_experts=64, experts_per_token=8, rope_theta=10_000.0,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=64, vocab_size=512, num_experts=8,
    experts_per_token=2, dtype=jnp.float32, remat=False)
