"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# mixtral-8x7b — MoE 8 experts top-2, GQA kv=8, SWA 4096
# [arXiv:2401.04088; hf]. SWA → runs long_500k.
CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_experts=8, experts_per_token=2, sliding_window=4096,
    rope_theta=1_000_000.0,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, num_experts=4,
    experts_per_token=2, sliding_window=16, dtype=jnp.float32, remat=False)
