"""Architecture config — see module docstring lines below."""
import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig


# internvl2-1b — VLM: InternViT frontend (STUB — input_specs supplies
# precomputed patch embeddings) + InternLM2 backbone [arXiv:2404.16821; hf]
CONFIG = ModelConfig(
    name="internvl2-1b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64, vision_prefix=256,
    rope_theta=1_000_000.0,
)
REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, vision_prefix=8,
    dtype=jnp.float32, remat=False)
