from repro.data.pipeline import SyntheticTokenStream, make_batch_iterator  # noqa: F401
