"""Deterministic synthetic token pipeline, sharded per host.

Production data loaders stream tokenized shards per host; here the "shard"
is a counter-based PRNG stream, which gives the same three properties the
trainer needs: determinism (resume from a step id reproduces the batch),
host-sharding (each data-parallel rank draws a disjoint stream), and
backpressure-free prefetch (pure compute). The generated text has Zipfian
token statistics plus a short-range copy structure so the LM loss actually
decreases during the example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-host batch
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (resume-safe)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        B, S, V = self.batch_size, self.seq_len, self.vocab_size
        # Zipfian marginals
        ranks = np.arange(1, V + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(V, size=(B, S + 1), p=probs).astype(np.int32)
        # short-range copy structure: repeat the previous token sometimes
        rep = rng.random((B, S + 1)) < 0.3
        rep[:, 0] = False
        idx = np.where(rep, np.roll(toks, 1, axis=1), toks)
        tokens = idx[:, :-1]
        labels = idx[:, 1:].copy()
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


def make_batch_iterator(stream: SyntheticTokenStream, *,
                        start_step: int = 0,
                        extras: Optional[dict] = None) -> Iterator[dict]:
    """Infinite iterator from a step offset (checkpoint-resume entry point)."""
    step = start_step
    while True:
        b = stream.batch_at(step)
        if extras:
            b = {**b, **extras}
        yield b
        step += 1
