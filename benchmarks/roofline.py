"""Roofline analysis from dry-run records (TPU v5e constants).

    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
    memory term     = HLO_bytes / (chips × 819 GB/s)
    collective term = collective_bytes / (chips × 50 GB/s/link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device on
the partitioned module → multiply by chips for the global numbers; the
ratios below use per-device values against per-chip peaks, which is
equivalent). collective_bytes is the loop-aware per-device ICI traffic
parsed from the partitioned HLO by launch/dryrun.py.

MODEL_FLOPS uses 6·N·D (train) / 2·N·D (inference step) with N = active
params — the "useful fraction" column catches remat/redundancy waste.
"""
from __future__ import annotations

import json
from typing import Optional

from repro import configs
from repro.configs.shapes import SHAPES
from repro.core.costmodel import TPU_V5E


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def roofline_row(rec: dict, chips: Optional[int] = None) -> Optional[dict]:
    if rec.get("status") != "OK":
        return None
    if chips is None:
        chips = 512 if rec.get("mesh") == "2x16x16" else 256
    flops_dev = rec["cost"].get("flops", 0.0)     # per-device, loop-aware
    bytes_dev = rec["cost"].get("bytes",
                                rec["cost"].get("bytes accessed", 0.0))
    coll_dev = rec["collectives"]["total"]
    t_compute = flops_dev / TPU_V5E.flops
    t_memory = bytes_dev / TPU_V5E.hbm_bw
    t_coll = coll_dev / TPU_V5E.ici_bw
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops_dev * chips, 1.0)
    bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind", "?"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom[0],
        "roofline_fraction": (t_compute / bound) if bound else 0.0,
        "model_flops": mf, "hlo_flops_global": flops_dev * chips,
        "useful_flop_fraction": useful,
        "peak_bytes_per_device": rec["bytes_per_device"]["peak_total"],
    }


def format_table(rows) -> str:
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':8s} {'comp(s)':>9s} "
           f"{'mem(s)':>9s} {'coll(s)':>9s} {'dominant':>10s} "
           f"{'roofl%':>7s} {'useful%':>8s} {'peakGB':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r is None:
            continue
        lines.append(
            f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
            f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
            f"{100*r['roofline_fraction']:6.1f}% "
            f"{100*min(r['useful_flop_fraction'],9.99):7.1f}% "
            f"{r['peak_bytes_per_device']/1e9:7.2f}")
    return "\n".join(lines)


def main(path: str = "dryrun_records.json"):
    with open(path) as f:
        records = json.load(f)
    rows = [roofline_row(r) for r in records if r.get("status") == "OK"]
    print(format_table(rows))
    skips = [r for r in records if r.get("status") == "SKIP"]
    for s in skips:
        print(f"SKIP  {s['arch']:18s} {s['shape']:12s} {s['mesh']:8s} "
              f"{s['skip_reason'][:60]}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_records.json")
