"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run fig2 fig3  # a subset
    PYTHONPATH=src python -m benchmarks.run --quick    # CI perf snapshot ->
                                                       # BENCH_quickstart.json
                                                       # + BENCH_formats.json

Prints ``name,us_per_call,derived`` CSV rows per the repo convention.
Wall-clock rows are CPU interpret-mode trends (kernel-correctness-level
numbers); the calibrated Ascend model provides the paper-figure
reproduction, and the TPU roofline (benchmarks/roofline.py over the dry-run
records) provides the target-hardware numbers. ``--format`` runs the
kernel/quick benches under any registered QuantFormat.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import PAPER_BATCH_SIZES, PAPER_GEMM_SHAPES
from repro.core import costmodel as cm
from repro.core import quant
from repro.core.quant import quantize
from repro.kernels import planning
from repro.kernels.gemm import gemm

BENCH_FORMAT = quant.DEFAULT_FORMAT      # set by main() from --format


def _time(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6      # µs


# ---------------------------------------------------------------------------
# Figure 2 — Split-K vs Data-Parallel across N×K and batch sizes
# ---------------------------------------------------------------------------

def bench_fig2_splitk_vs_dataparallel():
    """Execution time of INT4×FP16 for the paper's N×K grid (Ascend model),
    comparing Split-K against data-parallel — reproduces Fig. 2."""
    print("# fig2: name,us_per_call,derived(speedup_dp_over_splitk)")
    for (N, K) in PAPER_GEMM_SHAPES:
        for M in PAPER_BATCH_SIZES:
            t_dp = cm.w4a16_time_ascend(M, N, K, split_k=1) * 1e6
            s = cm.best_split_k_ascend(M, N, K)
            t_sk = cm.w4a16_time_ascend(M, N, K, split_k=s) * 1e6
            print(f"fig2/ascend_model/N{N}_K{K}_M{M}_S{s},"
                  f"{t_sk:.2f},{t_dp / t_sk:.3f}")


# ---------------------------------------------------------------------------
# Figure 3 — W4A16 speedup over native FP16
# ---------------------------------------------------------------------------

def bench_fig3_w4a16_vs_fp16():
    """Speedup of Split-K INT4×FP16 over FP16×FP16 (Ascend model) plus the
    TPU-v5e fused/decoupled comparison — reproduces Fig. 3 and the
    DESIGN.md adaptation claim."""
    print("# fig3: name,us_per_call,derived(speedup_over_fp16)")
    cap = 0.0
    for (N, K) in PAPER_GEMM_SHAPES:
        for M in PAPER_BATCH_SIZES:
            sp = cm.w4a16_speedup_ascend(M, N, K)
            cap = max(cap, sp)
            t = cm.w4a16_time_ascend(
                M, N, K, split_k=cm.best_split_k_ascend(M, N, K)) * 1e6
            print(f"fig3/ascend_model/N{N}_K{K}_M{M},{t:.2f},{sp:.3f}")
    print(f"fig3/ascend_model/max_speedup,0.0,{cap:.3f}  # paper: 1.48")
    for (N, K) in PAPER_GEMM_SHAPES[:4]:
        for M in (1, 16, 256):
            f = cm.fp16_time_tpu(M, N, K)
            fu = cm.w4a16_time_tpu_fused(M, N, K)
            de = cm.w4a16_time_tpu_decoupled(M, N, K, split_k=4)
            print(f"fig3/tpu_fused/N{N}_K{K}_M{M},{fu*1e6:.2f},{f/fu:.3f}")
            print(f"fig3/tpu_decoupled/N{N}_K{K}_M{M},{de*1e6:.2f},"
                  f"{f/de:.3f}")


# ---------------------------------------------------------------------------
# Kernel wall-time (CPU interpret — correctness-level trend only)
# ---------------------------------------------------------------------------

def bench_kernel_walltime():
    """Interpret-mode wall time of the actual kernels on scaled-down paper
    shapes: every strategy that supports the benched QuantFormat vs native
    bf16 GEMM, all through the planned execute path."""
    fmt = quant.get_format(BENCH_FORMAT)
    strategies = list(planning.strategies_for_format(fmt.name))
    baseline = "xla" if "xla" in strategies else strategies[0]
    print(f"# kernels: name,us_per_call,derived(ratio_vs_{baseline})  "
          f"[format={fmt.name}]")
    key = jax.random.PRNGKey(0)
    for (N, K) in [(512, 4096), (1024, 2048)]:
        for M in (1, 16):
            w = jax.random.normal(key, (K, N), jnp.float32)
            x = jax.random.normal(key, (M, K), jnp.bfloat16)
            qt = quantize(w, fmt, out_dtype=jnp.bfloat16)
            problem = planning.MatmulProblem.from_operands(x, qt)
            plans = {s: planning.plan_matmul(problem, strategy=s)
                     for s in strategies}
            t_base = _time(lambda: planning.execute(plans[baseline], x, qt))
            for strat in strategies:
                if strat == baseline:
                    continue
                t = _time(lambda s=strat: planning.execute(
                    plans[s], x, qt, interpret=True))
                print(f"kernels/{strat}/N{N}_K{K}_M{M},{t:.1f},"
                      f"{t / t_base:.2f}")
            wd = w.astype(jnp.bfloat16)
            t_g = _time(lambda: gemm(x, wd, interpret=True))
            print(f"kernels/gemm_bf16/N{N}_K{K}_M{M},{t_g:.1f},"
                  f"{t_g / t_base:.2f}")


# ---------------------------------------------------------------------------
# Planner decisions across the paper's GEMM grid
# ---------------------------------------------------------------------------

def bench_plans():
    """What the cost-model planner picks per paper (N, K, M) cell, with the
    predicted cost of every registered strategy next to the winner."""
    print("# plans: name,us_per_call,derived(strategy/split_k)")
    for (N, K) in PAPER_GEMM_SHAPES:
        for M in PAPER_BATCH_SIZES:
            problem = planning.MatmulProblem(
                M=M, N=N, K=K, group_size=128, act_dtype="bfloat16",
                out_dtype="bfloat16", backend="tpu")
            plan = planning.plan_matmul(problem, use_cache=False)
            # each strategy costed against ITS OWN plan (split_k etc.) —
            # the comparison the planner actually ran (format-eligible
            # strategies only; forcing a mismatched pair is refused)
            per = {s: planning.plan_matmul(problem, strategy=s)
                   for s in planning.strategies_for_format(problem.format)}
            costs = ";".join(
                f"{s}={planning.get_strategy(s).cost(problem, p) * 1e6:.1f}us"
                for s, p in per.items())
            t = planning.get_strategy(plan.strategy).cost(
                problem, per[plan.strategy])
            print(f"plans/N{N}_K{K}_M{M},{t*1e6:.2f},"
                  f"{plan.strategy}/S{plan.split_k}  # {costs}")


# ---------------------------------------------------------------------------
# Memory-capacity table (the paper's "fit larger models" conclusion)
# ---------------------------------------------------------------------------

def bench_capacity():
    """Weight bytes per arch: FP16 vs W4A16 (+scales) — the capacity win."""
    from repro import configs as C
    print("# capacity: name,us_per_call,derived(compression_ratio)")
    for arch in C.ARCHS:
        cfg = C.get_config(arch)
        n = cfg.param_count()
        fp16 = 2 * n
        w4 = 0.5 * n + 4 * n / cfg.group_size            # + fp32 scales
        print(f"capacity/{arch},0.0,{fp16 / w4:.3f}  "
              f"# {fp16/1e9:.1f}GB -> {w4/1e9:.1f}GB")


# ---------------------------------------------------------------------------
# Quick CI snapshot: shapes → ms + achieved GB/s, persisted as JSON so every
# CI run leaves a perf-trajectory artifact (BENCH_quickstart.json)
# ---------------------------------------------------------------------------

def bench_quick(out_path: str = "BENCH_quickstart.json") -> dict:
    """Planned execute on scaled-down paper shapes: wall-clock ms and
    achieved GB/s (quantized weight + activation + output bytes / time),
    written to ``out_path`` for the CI artifact upload."""
    print(f"# quick: name,us_per_call,derived(GB/s)  [format={BENCH_FORMAT}]")
    fmt = quant.get_format(BENCH_FORMAT)
    key = jax.random.PRNGKey(0)
    cells = []
    for (N, K) in [(512, 4096), (1024, 2048)]:
        for M in (1, 16):
            w = jax.random.normal(key, (K, N), jnp.float32)
            x = jax.random.normal(key, (M, K), jnp.bfloat16)
            qt = quantize(w, fmt, out_dtype=jnp.bfloat16)
            problem = planning.MatmulProblem.from_operands(x, qt)
            plan = planning.plan_matmul(problem)
            t_us = _time(lambda: planning.execute(plan, x, qt))
            moved = qt.nbytes_packed() + x.nbytes + M * N * 2
            gbps = moved / (t_us * 1e-6) / 1e9
            name = f"quick/{plan.strategy}/N{N}_K{K}_M{M}"
            print(f"{name},{t_us:.1f},{gbps:.2f}")
            cells.append({"name": name, "M": M, "N": N, "K": K,
                          "strategy": plan.strategy,
                          "ms": round(t_us / 1e3, 4),
                          "gbps": round(gbps, 3)})
    blob = {"format": BENCH_FORMAT, "backend": jax.default_backend(),
            "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# quick: wrote {len(cells)} cells -> {out_path}")
    return blob


# ---------------------------------------------------------------------------
# Fused-format sweep: the three Pallas fused kernels (w4a16/w8a16/w4a8) on
# the same shapes, persisted as BENCH_formats.json so the CI perf
# trajectory covers every format kernel from day one
# ---------------------------------------------------------------------------

_FUSED_BY_FORMAT = {
    "w4a16_g128": "fused",
    "w8a16_channel": "w8a16_fused",
    "w4a8_g128": "w4a8_fused",
}


def bench_formats(out_path: str = "BENCH_formats.json") -> dict:
    """Wall-clock of each format's fused Pallas kernel (interpret mode off
    TPU) next to the planner's pick for that format, per shape cell."""
    print("# formats: name,us_per_call,derived(GB/s)")
    key = jax.random.PRNGKey(0)
    cells = []
    for fmt_name, fused_strategy in _FUSED_BY_FORMAT.items():
        fmt = quant.get_format(fmt_name)
        for (N, K) in [(512, 2048)]:
            w = jax.random.normal(key, (K, N), jnp.float32)
            qt = quantize(w, fmt, out_dtype=jnp.bfloat16)
            for M in (1, 16):
                x = jax.random.normal(key, (M, K), jnp.bfloat16)
                problem = planning.MatmulProblem.from_operands(x, qt)
                plan = planning.plan_matmul(problem, strategy=fused_strategy)
                t_us = _time(lambda: planning.execute(
                    plan, x, qt, interpret=True))
                moved = qt.nbytes_packed() + x.nbytes + M * N * 2
                gbps = moved / (t_us * 1e-6) / 1e9
                picked = planning.plan_matmul(problem, use_cache=False)
                name = f"formats/{fmt_name}/{fused_strategy}/N{N}_K{K}_M{M}"
                print(f"{name},{t_us:.1f},{gbps:.2f}")
                cells.append({
                    "name": name, "format": fmt_name, "M": M, "N": N, "K": K,
                    "strategy": fused_strategy,
                    "planner_pick": picked.strategy,
                    "ms": round(t_us / 1e3, 4), "gbps": round(gbps, 3)})
    blob = {"backend": jax.default_backend(), "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# formats: wrote {len(cells)} cells -> {out_path}")
    return blob


# ---------------------------------------------------------------------------
# Serving sweep: the continuous-batching engine end to end — tokens/sec at
# several slot counts, persisted as BENCH_serving.json (CI artifact). This
# is the LiquidGEMM lesson: kernel wins only count when a batched serving
# loop drives them.
# ---------------------------------------------------------------------------

def bench_serving(out_path: str = "BENCH_serving.json") -> dict:
    """Engine decode throughput/latency per slot count on a reduced arch
    (CPU trend numbers; the shapes scale with batch, the regime does not)."""
    import dataclasses

    from repro import configs
    from repro.models import transformer as T
    from repro.runtime.engine import Request, ServingEngine

    print("# serving: name,us_per_call,derived(tok/s)")
    arch, P, G = "h2o-danube-1.8b", 8, 8
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="auto",
                              quant_format=BENCH_FORMAT)
    key = jax.random.PRNGKey(0)
    params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
    cells = []
    for B in (1, 2, 4):
        engine = ServingEngine(cfg, params, max_batch=B, max_prompt_len=P,
                               max_new_tokens=G)
        tokens = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
        reqs = [Request(rid=i, prompt=tokens[i], max_new_tokens=G)
                for i in range(B)]
        report = engine.run(reqs)
        ms_step = (report.decode_s / max(len(report.step_records), 1)) * 1e3
        name = f"serving/{arch}/B{B}_P{P}_G{G}"
        print(f"{name},{ms_step*1e3:.1f},{report.tokens_per_s:.2f}")
        cells.append({
            "name": name, "arch": arch, "batch": B, "prompt_len": P,
            "gen": G, "steps": report.steps,
            "decode_tokens": report.decode_tokens,
            "ms_per_step": round(ms_step, 3),
            "tok_per_s": round(report.tokens_per_s, 3),
            "prefill_ms": round(report.prefill_s * 1e3, 3),
            "cache_len": engine.cache_len,
        })
    blob = {"format": BENCH_FORMAT, "backend": jax.default_backend(),
            "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# serving: wrote {len(cells)} cells -> {out_path}")
    return blob


# ---------------------------------------------------------------------------
# Paged-KV sweep: ring vs paged engine at several prefix-share ratios —
# the KV cache is the other HBM-bound serving tensor (PAPER/LiquidGEMM);
# this persists throughput + peak pages as BENCH_paged_kv.json (CI artifact)
# ---------------------------------------------------------------------------

def bench_paged_kv(out_path: str = "BENCH_paged_kv.json") -> dict:
    """Ring vs paged engine decode at three prefix-share ratios (fraction
    of requests repeating one prompt): tokens/sec, peak live pages, and
    the zero-sharing worst case — the paged cache's capacity win."""
    import dataclasses

    from repro import configs
    from repro.models import transformer as T
    from repro.runtime.engine import Request, ServingEngine

    print("# paged_kv: name,us_per_call,derived(tok/s)")
    arch, P, G, B, R = "h2o-danube-1.8b", 8, 8, 4, 4
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="auto",
                              quant_format=BENCH_FORMAT)
    key = jax.random.PRNGKey(0)
    params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
    tokens = jax.random.randint(key, (R, P), 0, cfg.vocab_size)

    def requests(share_ratio):
        # the first ceil(share_ratio * R) requests repeat prompt 0
        n_shared = int(round(share_ratio * R))
        return [Request(rid=i,
                        prompt=tokens[0] if i < n_shared else tokens[i],
                        max_new_tokens=G) for i in range(R)]

    cells = []
    for ratio in (0.0, 0.5, 1.0):
        for mode in ("ring", "paged"):
            engine = ServingEngine(
                cfg, params, max_batch=B, max_prompt_len=P,
                max_new_tokens=G, paged=(mode == "paged"), page_size=4,
                prefill_chunk=4 if mode == "paged" else None)
            report = engine.run(requests(ratio))
            ms_step = (report.decode_s
                       / max(len(report.step_records), 1)) * 1e3
            name = f"paged_kv/{arch}/{mode}/share{ratio:.1f}"
            print(f"{name},{ms_step*1e3:.1f},{report.tokens_per_s:.2f}")
            cells.append({
                "name": name, "arch": arch, "mode": mode,
                "share_ratio": ratio, "batch": B, "prompt_len": P,
                "gen": G, "tok_per_s": round(report.tokens_per_s, 3),
                "ms_per_step": round(ms_step, 3),
                "prefill_ms": round(report.prefill_s * 1e3, 3),
                "peak_pages": report.peak_pages,
                "worst_case_pages": (engine.pages_slot * B
                                     if engine.paged else None),
                "cache_len": engine.cache_len,
            })
    blob = {"format": BENCH_FORMAT, "backend": jax.default_backend(),
            "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# paged_kv: wrote {len(cells)} cells -> {out_path}")
    return blob


# ---------------------------------------------------------------------------
# Speculative-decoding sweep: ngram-proposed verify vs plain paged decode at
# several prompt-repetition ratios — accepted-tokens/s is the figure of
# merit, persisted as BENCH_speculative.json (CI artifact)
# ---------------------------------------------------------------------------

def bench_speculative(out_path: str = "BENCH_speculative.json") -> dict:
    """Ngram self-speculation vs the plain paged engine on a dense arch
    (no SWA wrap clamp) at three prompt-repetition ratios. Each config is
    run twice on the same engine and the warmed run is measured, so the
    speedup column compares steady-state decode, not compile time.
    tok/s counts ACCEPTED tokens only — the honest speculative metric."""
    import dataclasses

    from repro import configs
    from repro.models import transformer as T
    from repro.runtime.engine import Request, ServingEngine

    print("# speculative: name,us_per_call,derived(speedup_vs_baseline)")
    arch, P, G, B, K = "starcoder2-7b", 16, 48, 4, 4
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="xla",
                              quant_format=BENCH_FORMAT)
    key = jax.random.PRNGKey(0)
    params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)

    def requests(reps):
        # reps=1: fully random per-request prompts (the ngram worst case);
        # reps=r: one P/r segment tiled r times, SHARED across the batch —
        # the prompt-lookup regime code serving actually sees (repetitive
        # prompts + prefix sharing between concurrent requests)
        seg = max(2, P // reps)
        toks = jax.random.randint(jax.random.fold_in(key, reps),
                                  (B, seg), 0, cfg.vocab_size)
        return [Request(rid=i,
                        prompt=jnp.tile(toks[0 if reps > 1 else i],
                                        -(-P // seg))[:P],
                        max_new_tokens=G) for i in range(B)]

    def run(speculate, reps):
        engine = ServingEngine(cfg, params, max_batch=B, max_prompt_len=P,
                               max_new_tokens=G, page_size=8,
                               prefill_chunk=8, speculate=speculate,
                               spec_k=K)
        engine.run(requests(reps))               # warm: compile + plans
        return engine.run(requests(reps))

    cells = []
    for reps in (1, 2, 4):
        base = run(None, reps)
        rep = run("ngram", reps)
        speedup = rep.tokens_per_s / max(base.tokens_per_s, 1e-9)
        ms_step = (rep.decode_s / max(len(rep.step_records), 1)) * 1e3
        name = f"speculative/{arch}/ngram_k{K}/reps{reps}"
        print(f"{name},{ms_step*1e3:.1f},{speedup:.3f}")
        cells.append({
            "name": name, "arch": arch, "proposer": "ngram", "spec_k": K,
            "batch": B, "prompt_len": P, "gen": G, "prompt_reps": reps,
            "proposed_tokens": rep.proposed_tokens,
            "accepted_tokens": rep.accepted_tokens,
            "acceptance_rate": round(rep.acceptance_rate, 4),
            "steps": rep.steps, "baseline_steps": base.steps,
            "tok_per_s": round(rep.tokens_per_s, 3),
            "baseline_tok_per_s": round(base.tokens_per_s, 3),
            "speedup_vs_baseline": round(speedup, 4),
            "ms_per_step": round(ms_step, 3),
        })
    blob = {"format": BENCH_FORMAT, "backend": jax.default_backend(),
            "spec_k": K, "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# speculative: wrote {len(cells)} cells -> {out_path}")
    return blob


# ---------------------------------------------------------------------------
# Paged-attention sweep: ring vs gather vs fused decode attention across
# context lengths and KV formats — bytes-moved (the paper's bottleneck
# metric) and tok/s per path, plus what the planner picks per backend;
# persisted as BENCH_paged_attn.json (CI artifact)
# ---------------------------------------------------------------------------

def bench_paged_attn(out_path: str = "BENCH_paged_attn.json") -> dict:
    """Op-level paged-attention sweep: the dense ring read, the XLA
    block-table gather (two passes over the KV window), and the fused
    Pallas kernel (one pass, in-VMEM dequant) on identical KV contents —
    at decode (q_len=1) plus the multi-query regimes (prefill chunks and
    k+1 speculative verify). Wall rows are CPU-trend numbers (the fused
    kernel runs in interpret mode off-TPU); the bytes/roofline columns
    are the decision metric — the gather's per-call HBM window
    materialization is what the fused path deletes."""
    import dataclasses

    from repro.core import quant as q
    from repro.kernels.paged_attention import fused_paged_attention
    from repro.models import attention
    from repro.runtime import kvcache as kvc

    print("# paged_attn: name,us_per_call,derived(tok/s)")
    B, Hq, Hkv, D, ps = 2, 4, 2, 64, 32
    key = jax.random.PRNGKey(0)

    def build(ctx, fmt_name):
        fmt = q.get_kv_format(fmt_name)
        T = ctx // ps
        nb = 1 + B * T
        kk, kv_ = jax.random.split(jax.random.fold_in(key, ctx))
        k = jax.random.normal(kk, (B, ctx, Hkv, D), jnp.float32)
        v = jax.random.normal(kv_, (B, ctx, Hkv, D), jnp.float32)
        kq, ks = q.kv_quantize(k, fmt)
        vq, vs = q.kv_quantize(v, fmt)

        def pack(x, tail):
            full = jnp.zeros((nb, ps) + tail, x.dtype)
            return full.at[1:].set(x.reshape(B * T, ps, *tail))

        pool = kvc.PagedKVCache(
            k_pool=pack(kq, (Hkv, D)), v_pool=pack(vq, (Hkv, D)),
            page_pos=jnp.full((nb, ps), -1, jnp.int32).at[1:].set(
                jnp.tile(jnp.arange(ctx, dtype=jnp.int32).reshape(T, ps),
                         (B, 1))),
            k_scale=None if ks is None else pack(ks, (Hkv,)),
            v_scale=None if vs is None else pack(vs, (Hkv,)))
        tables = (1 + jnp.arange(B * T, dtype=jnp.int32)).reshape(B, T)
        ring = attention.KVCache(
            k=k, v=v, pos=jnp.tile(jnp.arange(ctx, dtype=jnp.int32),
                                   (B, 1)))
        pos = jnp.full((B,), ctx - 1, jnp.int32)
        qv = jax.random.normal(jax.random.fold_in(key, 1),
                               (B, Hq, D), jnp.float32)
        return qv, pool, tables, pos, ring, fmt

    cells = []
    for fmt_name in ("kv_fp16", "kv8_channel"):
        quantized = q.get_kv_format(fmt_name).quantized
        for ctx in (128, 256, 512):
            qv, pool, tables, pos, ring, fmt = build(ctx, fmt_name)
            S = planning.choose_kv_partitions(B, Hkv, tables.shape[1])
            fns = {
                # ring stores raw cache-dtype rows — the same fp16 read
                # regardless of the pool's block format
                "ring": jax.jit(lambda qq, rr=ring, pp=pos:
                                attention.decode_attention(qq, rr, pp)),
                "gather": jax.jit(lambda qq, po=pool, tb=tables, pp=pos:
                                  kvc.paged_decode_attention(
                                      qq, po, tb, pp, fmt=fmt,
                                      out_dtype=jnp.float32)),
                "fused": jax.jit(lambda qq, po=pool, tb=tables, pp=pos:
                                 fused_paged_attention(
                                     qq, po, tb, pp, fmt=fmt,
                                     out_dtype=jnp.float32,
                                     kv_partitions=S)),
            }
            outs = {p: fn(qv) for p, fn in fns.items()}
            maxdiff = float(jnp.max(jnp.abs(outs["fused"] - outs["gather"])))
            problem = planning.AttentionProblem(
                B=B, Hq=Hq, Hkv=Hkv, D=D, cache_len=ctx, page_size=ps,
                kv_format=fmt_name, paged=True, act_bytes=4)
            picks = {
                be: planning.plan_attention(
                    dataclasses.replace(problem, backend=be)).path
                for be in ("cpu", "tpu")}
            for path, fn in fns.items():
                us = _time(fn, qv)
                gbytes = cm.paged_attn_bytes(
                    path, B, Hq, Hkv, D, ctx, act_bytes=4,
                    quantized=quantized and path != "ring",
                    kv_partitions=S if path == "fused" else 1)
                t_tpu = cm.attn_decode_time_tpu(
                    path, B, Hq, Hkv, D, ctx, act_bytes=4,
                    quantized=quantized and path != "ring",
                    kv_partitions=S if path == "fused" else 1)
                name = f"paged_attn/{fmt_name}/ctx{ctx}/{path}"
                print(f"{name},{us:.1f},{B / (us / 1e6):.1f}")
                cells.append({
                    "name": name, "path": path, "kv_format": fmt_name,
                    "ctx": ctx, "batch": B, "heads": Hq,
                    "kv_heads": Hkv, "head_dim": D, "page_size": ps,
                    "kv_partitions": S if path == "fused" else 1,
                    "q_len": 1,
                    "us_per_step": round(us, 2),
                    "tok_per_s": round(B / (us / 1e6), 2),
                    "bytes_moved": int(gbytes),
                    "roofline_tpu_us": round(t_tpu * 1e6, 3),
                    "planner_pick_cpu": picks["cpu"],
                    "planner_pick_tpu": picks["tpu"],
                    "fused_vs_gather_maxdiff": maxdiff,
                })

    # multi-query regimes over the same pools: chunked prefill (q_len =
    # the chunk, one slot per call) and speculative verify (q_len = k+1,
    # full batch) — gather still materializes the whole window per call,
    # so its bytes column is flat in q_len while the fused walk pays one
    # pass + O(q_len) partials
    from repro.kernels.paged_attention import fused_chunk_attention

    for fmt_name in ("kv_fp16", "kv8_channel"):
        quantized = q.get_kv_format(fmt_name).quantized
        for regime, Br, C in (("prefill_chunk", 1, 32), ("verify", B, 5)):
            for ctx in (128, 256, 512):
                _, pool, tables, _, _, fmt = build(ctx, fmt_name)
                tbl = tables[:Br]
                start = ctx - C
                positions = jnp.broadcast_to(
                    start + jnp.arange(C, dtype=jnp.int32), (Br, C))
                kk2 = jax.random.fold_in(key, 7 * ctx + C)
                qmq = jax.random.normal(kk2, (Br, C, Hq, D), jnp.float32)

                def rt(s, shape=(Br, C, Hkv, D)):
                    x = jax.random.normal(jax.random.fold_in(kk2, s),
                                          shape, jnp.float32)
                    return q.kv_dequantize(*q.kv_quantize(x, fmt), fmt=fmt,
                                           dtype=jnp.float32)

                kseg, vseg = rt(1), rt(2)
                problem = planning.AttentionProblem(
                    B=Br, Hq=Hq, Hkv=Hkv, D=D, cache_len=ctx, page_size=ps,
                    kv_format=fmt_name, paged=True, act_bytes=4, q_len=C)
                # the Split-K degree the planner would actually run
                # (occupancy-chosen, capped by the combine-traffic rule)
                S = planning.plan_attention(problem,
                                            path="fused").kv_partitions

                def gather_fn(qq, ks=kseg, vs=vseg, po=pool, tb=tbl,
                              pp=positions):
                    win = kvc.gather_window(po, tb, fmt=fmt,
                                            out_dtype=jnp.float32)
                    wpos = jnp.where(win.pos < pp[:, :1], win.pos, -1)
                    seq = attention.KVCache(
                        k=jnp.concatenate([win.k, ks], axis=1),
                        v=jnp.concatenate([win.v, vs], axis=1),
                        pos=jnp.concatenate([wpos, pp], axis=1))
                    return attention.prefix_chunk_attention(qq, seq, pp)

                def fused_fn(qq, ks=kseg, vs=vseg, po=pool, tb=tbl,
                             pp=positions, SS=S):
                    return fused_chunk_attention(
                        qq, ks, vs, po, tb, pp, fmt=fmt,
                        out_dtype=jnp.float32, kv_partitions=SS)

                fns = {"gather": jax.jit(gather_fn),
                       "fused": jax.jit(fused_fn)}
                outs = {p: fn(qmq) for p, fn in fns.items()}
                maxdiff = float(jnp.max(jnp.abs(outs["fused"]
                                                - outs["gather"])))
                picks = {
                    be: planning.plan_attention(
                        dataclasses.replace(problem, backend=be)).path
                    for be in ("cpu", "tpu")}
                for path, fn in fns.items():
                    us = _time(fn, qmq)
                    gbytes = cm.paged_attn_bytes(
                        path, Br, Hq, Hkv, D, ctx, act_bytes=4,
                        quantized=quantized,
                        kv_partitions=S if path == "fused" else 1,
                        q_len=C)
                    t_tpu = cm.attn_decode_time_tpu(
                        path, Br, Hq, Hkv, D, ctx, act_bytes=4,
                        quantized=quantized,
                        kv_partitions=S if path == "fused" else 1,
                        q_len=C)
                    name = (f"paged_attn/{fmt_name}/{regime}"
                            f"/ctx{ctx}/{path}")
                    tok_s = Br * C / (us / 1e6)
                    print(f"{name},{us:.1f},{tok_s:.1f}")
                    cells.append({
                        "name": name, "path": path, "kv_format": fmt_name,
                        "regime": regime, "ctx": ctx, "batch": Br,
                        "heads": Hq, "kv_heads": Hkv, "head_dim": D,
                        "page_size": ps,
                        "kv_partitions": S if path == "fused" else 1,
                        "q_len": C,
                        "us_per_step": round(us, 2),
                        "tok_per_s": round(tok_s, 2),
                        "bytes_moved": int(gbytes),
                        "roofline_tpu_us": round(t_tpu * 1e6, 3),
                        "planner_pick_cpu": picks["cpu"],
                        "planner_pick_tpu": picks["tpu"],
                        "fused_vs_gather_maxdiff": maxdiff,
                    })
    blob = {"format": BENCH_FORMAT, "backend": jax.default_backend(),
            "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# paged_attn: wrote {len(cells)} cells -> {out_path}")
    return blob


# ---------------------------------------------------------------------------
# Front-door sweep: the async HTTP serving path under rising arrival rates —
# real-socket SSE clients against the bounded admission queue; served ratio,
# TTFT/e2e quantiles and 429/408 shed counts land in BENCH_frontdoor.json
# ---------------------------------------------------------------------------

def bench_frontdoor(out_path: str = "BENCH_frontdoor.json") -> dict:
    """Arrival-rate sweep over the asyncio front door (reduced danube):
    R real HTTP clients spaced ``gap_ms`` apart stream SSE tokens through
    a small admission queue; faster arrivals shed load as 429 instead of
    queueing past the SLO. A plain ``engine.run`` pass warms compile
    caches first, so the sweep measures serving, not tracing."""
    import asyncio
    import dataclasses

    from repro import configs
    from repro.models import transformer as T
    from repro.runtime.engine import Request, ServingEngine
    from repro.runtime.frontdoor import (FrontDoor, QueueSettings,
                                         sse_decode_tokens)

    print("# frontdoor: name,us_per_call,derived(served/total)")
    arch, P, G, B, R, QD = "h2o-danube-1.8b", 8, 8, 2, 6, 3
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="xla",
                              quant_format=BENCH_FORMAT)
    key = jax.random.PRNGKey(0)
    params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
    tokens = jax.random.randint(key, (R, P), 0, cfg.vocab_size)
    prompts = [[int(t) for t in tokens[i]] for i in range(R)]

    engine = ServingEngine(cfg, params, max_batch=B, max_prompt_len=P,
                           max_new_tokens=G, page_size=4, prefill_chunk=4,
                           admission="priority")
    engine.run([Request(rid=i, prompt=prompts[i], max_new_tokens=G)
                for i in range(B)])                # warm: compile + plans

    async def client(port, prompt, delay):
        await asyncio.sleep(delay)
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        body = json.dumps({"prompt": prompt, "max_new_tokens": G}).encode()
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: bench\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        payload = await reader.read()
        writer.close()
        if b" 200 " not in payload.split(b"\r\n", 1)[0]:
            return None
        return sse_decode_tokens(payload)

    async def sweep(gap_s):
        fd = FrontDoor(engine,
                       settings=QueueSettings(queue_depth=QD))
        await fd.serve()
        t0 = time.perf_counter()
        outs = await asyncio.gather(*(
            client(fd.port, prompts[i], i * gap_s) for i in range(R)))
        report = await fd.shutdown()
        return outs, report, time.perf_counter() - t0

    cells = []
    for gap_ms in (0, 30, 120):
        outs, report, wall = asyncio.run(sweep(gap_ms / 1e3))
        served = sum(1 for o in outs if o is not None)
        ls, ts = report.latency_stats(), report.ttft_stats()
        name = f"frontdoor/{arch}/gap{gap_ms}ms"
        print(f"{name},{wall*1e6:.0f},{served}/{R}")
        cells.append({
            "name": name, "arch": arch, "gap_ms": gap_ms,
            "queue_depth": QD, "batch": B, "requests": R,
            "served": served, "rejected_429": report.rejected_429,
            "rejected_408": report.rejected_408,
            "peak_queue_depth": report.peak_queue_depth,
            "ttft_p50_ms": round(ts["p50"] * 1e3, 3),
            "ttft_p99_ms": round(ts["p99"] * 1e3, 3),
            "e2e_p50_ms": round(ls["p50"] * 1e3, 3),
            "e2e_p99_ms": round(ls["p99"] * 1e3, 3),
            "tok_per_s": round(report.tokens_per_s, 3),
            "wall_s": round(wall, 3),
        })
    blob = {"format": BENCH_FORMAT, "backend": jax.default_backend(),
            "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# frontdoor: wrote {len(cells)} cells -> {out_path}")
    return blob


# ---------------------------------------------------------------------------
# Warm-prefix-cache sweep: Zipf-distributed prompt reuse against the
# allocator's warm retention budget — warm hit rate and prefill steps saved
# per (skew, budget) cell, persisted as BENCH_prefix_cache.json (CI artifact)
# ---------------------------------------------------------------------------

def bench_prefix_cache(out_path: str = "BENCH_prefix_cache.json") -> dict:
    """Zipfian arrival-trace sweep over the warm prefix cache: R requests
    draw their prompt from a pool of U distinct page-aligned prompts with
    Zipf(skew) popularity, so hot prompts return after their slot has
    released its pages. Each skew level runs at three warm budgets (off /
    half the pool / the whole pool + slack); warm hit rate and
    prefill-steps-saved are the figures of merit — a full warm hit admits
    with zero prefill steps."""
    import dataclasses

    from repro import configs
    from repro.models import transformer as T
    from repro.runtime.engine import Request, ServingEngine

    print("# prefix_cache: name,us_per_call,derived(warm_hit_rate)")
    # dense arch: an SWA window would wrap decode over the prompt pages
    # and unpublish the very chains warm retention wants to keep
    arch, P, G, B, R, U = "starcoder2-7b", 16, 4, 2, 12, 6
    page = 4                                   # P/page = 4-page chains
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="xla",
                              quant_format=BENCH_FORMAT)
    key = jax.random.PRNGKey(0)
    params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
    pool = jax.random.randint(key, (U, P), 0, cfg.vocab_size)

    def trace(skew):
        # rank-r prompt drawn with probability ∝ 1/(r+1)^skew; B=2 slots
        # over R=12 arrivals means hot prompts keep returning after their
        # pages were released — exactly the regime warm retention targets
        w = jnp.arange(1, U + 1, dtype=jnp.float32) ** -skew
        picks = jax.random.choice(jax.random.fold_in(key, int(skew * 10)),
                                  U, (R,), p=w / w.sum())
        return [Request(rid=i, prompt=pool[int(picks[i])],
                        max_new_tokens=G) for i in range(R)]

    def engine_for(mb):
        return ServingEngine(cfg, params, max_batch=B, max_prompt_len=P,
                             max_new_tokens=G, page_size=page,
                             prefill_chunk=page, warm_cache_mb=mb)

    chain_mb = (engine_for(0.0).alloc.block_bytes
                * (P // page)) / (1 << 20)     # one full prompt chain
    cells = []
    for skew in (0.0, 1.0, 1.8):
        for budget_mb in (0.0, chain_mb * U / 2, chain_mb * (U + B)):
            engine = engine_for(budget_mb)
            engine.run(trace(skew))            # warm: compile + plans
            report = engine.run(trace(skew))
            admits = report.warm_hits + report.warm_misses
            hit_rate = report.warm_hits / max(admits, 1)
            name = (f"prefix_cache/{arch}/zipf{skew:.1f}/"
                    f"warm{budget_mb:.2f}MiB")
            print(f"{name},{report.decode_s*1e6:.0f},{hit_rate:.3f}")
            cells.append({
                "name": name, "arch": arch, "zipf_skew": skew,
                "warm_cache_mb": round(budget_mb, 4), "batch": B,
                "prompt_len": P, "gen": G, "requests": R,
                "distinct_prompts": U, "page_size": page,
                "warm_hits": report.warm_hits,
                "warm_misses": report.warm_misses,
                "warm_hit_rate": round(hit_rate, 4),
                "prefill_steps_saved": report.prefill_steps_saved,
                "steps": report.steps,
                "tok_per_s": round(report.tokens_per_s, 3),
            })
    blob = {"format": BENCH_FORMAT, "backend": jax.default_backend(),
            "cells": cells}
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"# prefix_cache: wrote {len(cells)} cells -> {out_path}")
    return blob


BENCHES = {
    "fig2": bench_fig2_splitk_vs_dataparallel,
    "fig3": bench_fig3_w4a16_vs_fp16,
    "kernels": bench_kernel_walltime,
    "capacity": bench_capacity,
    "plans": bench_plans,
    "formats": bench_formats,
    "serving": bench_serving,
    "paged_kv": bench_paged_kv,
    "paged_attn": bench_paged_attn,
    "speculative": bench_speculative,
    "frontdoor": bench_frontdoor,
    "prefix_cache": bench_prefix_cache,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="*", metavar="bench",
                    help=f"subset of {list(BENCHES)} (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="run the quick perf snapshot, the fused-format "
                         "sweep, the serving sweep, the ring-vs-paged KV "
                         "sweep, the paged-attention path sweep, the "
                         "speculative sweep, the front-door arrival "
                         "sweep and the warm-prefix-cache sweep, writing "
                         "BENCH_quickstart.json, BENCH_formats.json, "
                         "BENCH_serving.json, BENCH_paged_kv.json, "
                         "BENCH_paged_attn.json, BENCH_speculative.json, "
                         "BENCH_frontdoor.json and BENCH_prefix_cache.json "
                         "(the CI artifacts)")
    ap.add_argument("--format", default=quant.DEFAULT_FORMAT,
                    help="QuantFormat name for quantized benches "
                         "(w4a16_g128 | w8a16_channel | w4a8_g128 | ...)")
    ap.add_argument("--out", default="BENCH_quickstart.json",
                    help="--quick output path")
    args = ap.parse_args(argv)

    global BENCH_FORMAT
    BENCH_FORMAT = quant.get_format(args.format).name
    if args.quick:
        bench_quick(args.out)
        bench_formats()
        bench_serving()
        bench_paged_kv()
        bench_paged_attn()
        bench_speculative()
        bench_frontdoor()
        bench_prefix_cache()
        return
    for name in args.benches or list(BENCHES):
        if name not in BENCHES:
            ap.error(f"unknown bench {name!r}; one of {list(BENCHES)}")
        BENCHES[name]()


if __name__ == "__main__":
    main()
