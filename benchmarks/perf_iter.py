"""Perf-iteration driver for the §Perf hillclimb.

Re-lowers one (arch × shape × mesh) cell with overrides (microbatches,
fsdp flags, remat, sharding variants), prints the roofline terms next to
the baseline record, and emits a log line for EXPERIMENTS.md:

    PYTHONPATH=src python -m benchmarks.perf_iter --arch llama3-405b \
        --shape train_4k --set microbatches=32 --baseline dryrun_records.json

Must run in a fresh process per invocation (512-device XLA flag).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json

from repro.launch import dryrun
from repro.launch import presets


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    try:
        v = int(v)
    except ValueError:
        if v in ("true", "false"):
            v = v == "true"
        elif v in ("bf16", "f32"):
            import jax.numpy as jnp
            v = jnp.bfloat16 if v == "bf16" else jnp.float32
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="TrainSettings override, e.g. microbatches=32")
    ap.add_argument("--cfg-set", action="append", default=[],
                    help="ModelConfig override, e.g. remat=false")
    ap.add_argument("--baseline", default="dryrun_records.json")
    ap.add_argument("--plan-cache", default=None,
                    help="plan-cache JSON to merge this cell's decisions "
                         "into (keyed on backend='tpu' / full-config dims — "
                         "warm-starts TPU serving of the full model, not "
                         "CPU-reduced demos)")
    args = ap.parse_args()

    from repro.kernels import planning
    if args.plan_cache and os.path.exists(args.plan_cache):
        planning.load_plan_cache(args.plan_cache, tolerant=True)

    # patch the preset for this run
    st = presets.settings_for(args.arch)
    if args.set:
        st = dataclasses.replace(st, **dict(map(parse_override, args.set)))
        presets.PRESETS[args.arch] = st
    if args.cfg_set:
        from repro import configs as C
        overrides = dict(map(parse_override, args.cfg_set))
        orig_get = C.get_config

        def patched(arch):
            cfg = orig_get(arch)
            if arch == args.arch:
                cfg = dataclasses.replace(cfg, **overrides)
            return cfg
        C.get_config = patched
        dryrun.configs.get_config = patched

    rec = dryrun.run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                          verbose=False)
    import sys
    sys.path.insert(0, os.path.dirname(__file__))
    from roofline import roofline_row

    row = roofline_row(rec) if rec["status"] == "OK" else None
    base_row = None
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            for r in json.load(f):
                if (r["arch"], r["shape"], r["mesh"]) == (
                        rec["arch"], rec["shape"], rec["mesh"]):
                    base_row = roofline_row(r) if r["status"] == "OK" else None
    plans = _cell_plans(planning, args.arch, args.shape)
    print(json.dumps({"overrides": args.set + args.cfg_set,
                      "status": rec["status"],
                      "error": rec.get("error"),
                      "baseline": base_row, "variant": row,
                      "plans": plans},
                     indent=1, default=str))
    if args.plan_cache:
        planning.save_plan_cache(args.plan_cache)


def _cell_plans(planning, arch, shape_name):
    """Planner decisions for this cell's quantized serving GEMMs (printed
    next to the roofline so the hillclimb sees dispatch choices change)."""
    import jax.numpy as jnp
    from repro import configs as C
    from repro.configs.shapes import SHAPES

    cfg = C.get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train" or not cfg.quantize_serve:
        return None
    M = shape.global_batch if shape.kind == "decode" \
        else shape.global_batch * shape.seq_len
    from repro.core import quant

    base_fmt = quant.get_format(getattr(cfg, "quant_format",
                                        quant.DEFAULT_FORMAT))
    out = {}
    for K, N in [(cfg.d_model, cfg.q_dim), (cfg.q_dim, cfg.d_model),
                 (cfg.d_model, cfg.d_ff), (cfg.d_ff, cfg.d_model)]:
        g = next((gg for gg in (cfg.group_size, 64, 32) if K % gg == 0), None)
        if g is None:
            continue
        fmt = base_fmt.with_group_size(g)
        if fmt.scale_granularity != "group":
            g = K                    # channel/tensor: one group spans K
        problem = planning.MatmulProblem(
            M=M, N=N, K=K, group_size=g,
            act_dtype=str(jnp.dtype(cfg.dtype)),
            out_dtype=str(jnp.dtype(cfg.dtype)), backend="tpu",
            format=fmt.name)
        out[problem.layer_key] = planning.plan_matmul(problem).to_dict()
    return out


if __name__ == "__main__":
    main()
