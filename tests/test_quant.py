"""Property tests for the INT4 quantization core.

Formerly hypothesis-driven; now a deterministic parametrized sweep over the
same sampled domains (shapes × group sizes × seeds) so the suite runs on
containers without hypothesis installed.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant

DIMS = [(64, 16), (128, 8), (256, 32), (64, 128)]
GROUPS = [16, 32, 64]
SEEDS = [0, 7, 1234, 2 ** 31 - 1]


@pytest.mark.parametrize("shape,seed", itertools.product(DIMS, SEEDS))
def test_pack_unpack_bijection(shape, seed):
    K, N = shape
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
    packed = quant.pack_int4(jnp.asarray(q))
    assert packed.shape == (K // 2, N) and packed.dtype == jnp.int8
    out = quant.unpack_int4(packed)
    np.testing.assert_array_equal(np.asarray(out), q)


@pytest.mark.parametrize(
    "shape,g,symmetric,seed",
    [(shape, g, sym, seed)
     for shape, g, sym, seed in itertools.product(
         DIMS, GROUPS, (True, False), SEEDS[:2])
     if shape[0] % g == 0])
def test_quantize_error_bound(shape, g, symmetric, seed):
    K, N = shape
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    qt = quant.quantize(w, group_size=g, symmetric=symmetric)
    wd = quant.dequantize(qt)
    bound = jnp.repeat(quant.quantization_error_bound(qt), g, axis=0)
    # |w - deq(q(w))| <= s/2 + tiny fp slack
    assert bool(jnp.all(jnp.abs(wd - w) <= bound * 1.001 + 1e-6))


@pytest.mark.parametrize("shape,seed", itertools.product(DIMS, SEEDS))
def test_quantized_matmul_close_to_dense(shape, seed):
    K, N = shape
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4, K)).astype(np.float32))
    qt = quant.quantize(w, group_size=32)
    y = quant.w4a16_matmul_ref(x, qt)
    y_exact = x @ quant.dequantize(qt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_exact),
                               rtol=1e-5, atol=1e-4)


def test_memory_footprint_4x():
    """The paper's premise: INT4 weights are ~4x smaller than FP16."""
    w = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024), jnp.float32)
    qt = quant.quantize(w, group_size=128, scale_dtype=jnp.bfloat16,
                        out_dtype=jnp.bfloat16)
    fp16_bytes = w.size * 2
    ratio = fp16_bytes / qt.nbytes_packed()
    assert ratio > 3.8, ratio        # 4x minus scale overhead


def test_quantize_rejects_bad_group():
    w = jnp.zeros((100, 8))
    with pytest.raises(ValueError):
        quant.quantize(w, group_size=64)


@pytest.mark.parametrize("seed,skew", [(0, "lognormal"), (1, "shifted"),
                                       (2, "bimodal")])
def test_asymmetric_skewed_distributions(seed, skew):
    """Asymmetric (zeros != None) correctness on skewed weights: the
    quantize→dequantize error respects the s/2 bound elementwise, and
    w4a16_matmul_ref stays within the induced |x| @ (s/2) matmul bound of
    the dense product."""
    rng = np.random.default_rng(seed)
    K, N, g = 256, 32, 64
    if skew == "lognormal":
        w = rng.lognormal(0.0, 0.5, size=(K, N))
    elif skew == "shifted":
        w = rng.normal(3.0, 0.25, size=(K, N))      # far from zero
    else:
        w = np.where(rng.random((K, N)) < 0.5,
                     rng.normal(-2.0, 0.1, (K, N)),
                     rng.normal(5.0, 0.1, (K, N)))
    w = jnp.asarray(w.astype(np.float32))
    qt = quant.quantize(w, group_size=g, symmetric=False)
    assert qt.zeros is not None

    wd = np.asarray(quant.dequantize(qt))
    bound = np.repeat(np.asarray(quant.quantization_error_bound(qt)),
                      g, axis=0)
    assert (np.abs(wd - np.asarray(w)) <= bound * 1.001 + 1e-6).all()

    x = jnp.asarray(rng.normal(size=(8, K)).astype(np.float32))
    y = np.asarray(quant.w4a16_matmul_ref(x, qt))
    dense = np.asarray(x) @ np.asarray(w)
    mm_bound = np.abs(np.asarray(x)) @ bound
    assert (np.abs(y - dense) <= mm_bound * 1.001 + 1e-3).all()
    # and asymmetric beats symmetric on these skewed ranges
    err_sym = np.abs(np.asarray(quant.dequantize(
        quant.quantize(w, group_size=g))) - np.asarray(w)).mean()
    assert np.abs(wd - np.asarray(w)).mean() < err_sym


def test_zero_point_asymmetric():
    """Asymmetric quantization recovers a strictly positive weight matrix
    better than symmetric (the zero-point earns its storage)."""
    key = jax.random.PRNGKey(1)
    w = jax.random.uniform(key, (128, 32), jnp.float32, 1.0, 3.0)
    err_sym = jnp.abs(quant.dequantize(quant.quantize(w, group_size=64)) - w).mean()
    err_asym = jnp.abs(quant.dequantize(
        quant.quantize(w, group_size=64, symmetric=False)) - w).mean()
    assert float(err_asym) < float(err_sym)
