"""Elastic rescale: re-lower the same step on a degraded mesh (lost slice).

Runs in a subprocess with 512 fake devices: lowers h2o train on the full
16×16 mesh, then rebuilds a 15×16 mesh via `degraded_mesh` (one data row
lost) and re-lowers — proving the sharding rules hold off the power-of-two
path, which is what elastic restart on survivors requires.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import SHAPES, input_specs
from repro.core.compat import set_mesh
from repro.launch.mesh import make_production_mesh, degraded_mesh
from repro.launch.presets import settings_for
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import steps as rsteps

arch = "h2o-danube-1.8b"
cfg = configs.get_config(arch)
shape = SHAPES["train_4k"]
settings = settings_for(arch)
params_abs = T.abstract_params(cfg)
opt_cfg = AdamWConfig(state_dtype=settings.opt_dtype)
opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_abs)
specs = input_specs(cfg, shape)
inputs_abs = {"batch": specs["batch"],
              "step": jax.ShapeDtypeStruct((), jnp.int32)}

import dataclasses
out = {}
for name, mesh in [("full", make_production_mesh()),
                   ("degraded", degraded_mesh(make_production_mesh(),
                                              drop_data=1))]:
    if name == "degraded":
        # elastic restart keeps per-device batch constant: 256 → 240 on the
        # 15×16 survivor mesh (the data pipeline takes any per-host batch)
        shape2 = dataclasses.replace(shape, global_batch=240)
        specs = input_specs(cfg, shape2)
        inputs_abs = {"batch": specs["batch"],
                      "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with set_mesh(mesh):
        fn = rsteps.jit_train_step(cfg, mesh, settings, params_abs,
                                   inputs_abs, opt_cfg)
        compiled = fn.lower(params_abs, opt_abs, inputs_abs).compile()
    m = compiled.memory_analysis()
    out[name] = {
        "devices": int(mesh.devices.size),
        "peakGB": round((m.argument_size_in_bytes + m.temp_size_in_bytes
                         + m.output_size_in_bytes) / 1e9, 2),
    }
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_degraded_mesh_relowers():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["full"]["devices"] == 256
    assert out["degraded"]["devices"] == 240     # 15 × 16 survivors
    assert out["degraded"]["peakGB"] < 16.0
