"""Checkpoint store: roundtrip, atomicity, quantized leaves, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.quant import quantize


def make_tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "layers": {"w": jax.random.normal(k1, (8, 16), jnp.bfloat16),
                   "b": jnp.zeros((16,), jnp.float32)},
        "count": jnp.asarray(7, jnp.int32),
        "qt": quantize(jax.random.normal(k2, (64, 32)), group_size=32),
    }


def test_roundtrip(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, tree, extra={"note": "hi"})
    like = jax.tree.map(lambda x: x, tree,
                        is_leaf=lambda x: hasattr(x, "packed"))
    out, step, extra = restore_checkpoint(str(tmp_path), like)
    assert step == 3 and extra == {"note": "hi"}
    np.testing.assert_array_equal(np.asarray(out["layers"]["w"],
                                             np.float32),
                                  np.asarray(tree["layers"]["w"], np.float32))
    np.testing.assert_array_equal(np.asarray(out["qt"].packed),
                                  np.asarray(tree["qt"].packed))
    assert out["qt"].group_size == 32


def test_latest_and_multiple_steps(tmp_path):
    tree = make_tree(jax.random.PRNGKey(1))
    for s in (1, 5, 12):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 12
    _, step, _ = restore_checkpoint(str(tmp_path), tree, step=5)
    assert step == 5


def test_no_checkpoint_returns_none(tmp_path):
    out, step, extra = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)})
    assert out is None and step is None


def test_partial_write_ignored(tmp_path):
    """A crash mid-save (tmp dir left behind) must not corrupt restore."""
    tree = make_tree(jax.random.PRNGKey(2))
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "tmp.2")          # simulated dead partial write
    (tmp_path / "tmp.2" / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 1
    out, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 1 and out is not None


def test_shape_mismatch_fails_loudly(tmp_path):
    tree = make_tree(jax.random.PRNGKey(3))
    save_checkpoint(str(tmp_path), 1, tree)
    bad = dict(tree, layers={"w": jnp.zeros((9, 16), jnp.bfloat16),
                             "b": tree["layers"]["b"]})
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), bad)
