"""Per-arch reduced-config smoke tests + serving-path consistency.

Every assigned architecture instantiates its REDUCED config and runs one
forward + one train step on CPU, asserting output shapes and finiteness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers, transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import steps as rsteps

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, key=KEY, batch=B, seq=S):
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    out = {"tokens": toks, "labels": toks}
    if cfg.vision_prefix:
        out["vision_embeds"] = jax.random.normal(
            key, (batch, cfg.vision_prefix, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        out["audio_embeds"] = jax.random.normal(
            key, (batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return out


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get_reduced(arch)
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg)
    logits = T.forward(params, cfg, batch["tokens"],
                       prefix_embeds=batch.get("vision_embeds"),
                       audio_embeds=batch.get("audio_embeds"))
    S_total = S + (cfg.vision_prefix or 0)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt_cfg = AdamWConfig(lr=1e-3)
    settings = rsteps.TrainSettings(microbatches=2)
    step = jax.jit(rsteps.make_train_step(cfg, opt_cfg, settings))
    opt = adamw_init(params, opt_cfg)
    p2, o2, m = step(params, opt,
                     {"batch": batch, "step": jnp.zeros((), jnp.int32)})
    assert bool(jnp.isfinite(m["loss"])) and bool(jnp.isfinite(m["grad_norm"]))
    # params actually moved
    diff = jax.tree.reduce(
        lambda a, l: a + float(jnp.abs(l[0] - l[1]).sum()),
        jax.tree.map(lambda a, b: (a, b), p2, params), 0.0)
    assert diff > 0.0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_full_config_exact(arch):
    """The FULL config matches the assignment table (spot invariants)."""
    c = configs.get_config(arch)
    assert c.name == arch
    table = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    L, d, H, kv, ff, V = table[arch]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (L, d, H, kv, ff, V)
    if arch == "mixtral-8x7b":
        assert (c.num_experts, c.experts_per_token) == (8, 2)
    if arch == "olmoe-1b-7b":
        assert (c.num_experts, c.experts_per_token) == (64, 8)
    if arch == "hymba-1.5b":
        assert c.ssm_state == 16 and c.family == "hybrid"
    if arch == "rwkv6-7b":
        assert c.family == "rwkv"
    if arch == "whisper-small":
        assert c.family == "encdec" and c.encoder_layers == 12


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "rwkv6-7b",
                                  "hymba-1.5b", "mixtral-8x7b",
                                  "whisper-small"])
def test_prefill_decode_matches_forward(arch):
    """Greedy decode over a prefilled cache reproduces teacher-forced logits."""
    cfg = configs.get_reduced(arch)
    if cfg.family == "moe":
        # dropless routing for the consistency check: capacity dropping is
        # order-dependent, so teacher-forcing vs decode legitimately diverge
        cfg = dataclasses.replace(cfg, moe_capacity_factor=100.0)
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg)
    toks = batch["tokens"]
    full = T.forward(params, cfg, toks,
                     audio_embeds=batch.get("audio_embeds"))
    last, state = T.prefill(params, cfg, toks[:, :S - 1], cache_len=S + 4,
                            audio_embeds=batch.get("audio_embeds"))
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, S - 2]),
                               rtol=2e-2, atol=2e-3)
    logits, _ = T.decode_step(params, cfg, state, toks[:, S - 1],
                              jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, S - 1]),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("arch", ["granite-20b", "olmoe-1b-7b"])
def test_w4a16_serving_close_to_dense(arch):
    """W4A16-quantized model (the paper's deployment) tracks the dense model.

    Random-init reduced models have near-tied top logits, so exact argmax
    equality is a coin flip under any quantization noise; the sound check
    is that the quantized greedy token stays among the dense model's top
    candidates (chance level ~5/vocab ≈ 0.01%)."""
    cfg = configs.get_reduced(arch)
    cfg = dataclasses.replace(cfg, w4a16_strategy="xla")
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg, batch=4, seq=32)
    dense = np.asarray(T.forward(params, cfg, batch["tokens"]), np.float32)
    qparams = layers.quantize_tree(params, group_size=cfg.group_size,
                                   min_size=0)
    quant = np.asarray(T.forward(qparams, cfg, batch["tokens"]), np.float32)
    corr = np.corrcoef(dense.ravel(), quant.ravel())[0, 1]
    assert corr > 0.85, corr
    # greedy decode stays within the dense model's top-5 candidates
    q_top1 = np.argmax(quant, -1)
    d_top5 = np.argsort(dense, axis=-1)[..., -5:]
    in_top5 = np.mean(np.any(d_top5 == q_top1[..., None], axis=-1))
    assert in_top5 > 0.6, in_top5


def test_sliding_window_masks_old_tokens():
    """SWA: token attends only within its window (h2o/mixtral/hymba semantics)."""
    from repro.models import attention
    Bq, Sq, H, D = 1, 32, 2, 8
    q = jax.random.normal(KEY, (Bq, Sq, H, D), jnp.float32)
    k = jax.random.normal(KEY, (Bq, Sq, H, D), jnp.float32)
    v = jax.random.normal(KEY, (Bq, Sq, H, D), jnp.float32)
    full = attention.chunked_attention(q, k, v, causal=True, window=0,
                                       q_chunk=8, kv_chunk=8)
    win = attention.chunked_attention(q, k, v, causal=True, window=4,
                                      q_chunk=8, kv_chunk=8)
    # early tokens (inside window) identical; late tokens differ
    np.testing.assert_allclose(np.asarray(win[:, :4]),
                               np.asarray(full[:, :4]), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(win[:, -1] - full[:, -1]).max()) > 1e-4


def test_long_context_eligibility_rules():
    from repro.configs.shapes import SHAPES, skip_reason
    long = SHAPES["long_500k"]
    runs = {a for a in configs.ARCHS
            if skip_reason(configs.get_config(a), long) is None}
    assert runs == {"h2o-danube-1.8b", "rwkv6-7b", "mixtral-8x7b",
                    "hymba-1.5b"}
