"""Serving-engine tests: slot scheduler + continuous batching, prefix-aware
KV-cache sizing (the PR-4 regression), shard-local planning, and the
8-fake-device parity suite (sharded engine decode token-identical to
single-device, plans keyed on per-rank shapes)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import serve_cache_len
from repro.kernels import planning
from repro.models import attention
from repro.models import transformer as T
from repro.runtime import steps as rsteps
from repro.runtime.engine import (
    Request, ServingEngine, insert_slot, reset_slot,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")
KEY = jax.random.PRNGKey(0)


class FakeMesh:
    """Spec-level mesh stand-in (shape/axis_names only)."""

    def __init__(self, sizes):
        self.shape = dict(sizes)
        self.axis_names = tuple(sizes)


def _params(cfg, quantized=True):
    p = T.init_params(KEY, cfg)
    return T.quantize_params(p, cfg, min_size=0) if quantized else p


def _requests(cfg, n, P, G, *, arrival_every=0):
    toks = jax.random.randint(KEY, (n, P), 0, cfg.vocab_size)
    reqs = []
    for i in range(n):
        kw = {}
        if cfg.vision_prefix:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, i),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            kw["audio_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, i),
                (cfg.encoder_seq, cfg.d_model), cfg.dtype)
        reqs.append(Request(rid=i, prompt=toks[i], max_new_tokens=G,
                            arrival_step=i * arrival_every, **kw))
    return reqs


# ---------------------------------------------------------------------------
# prefix-aware cache sizing (satellite bugfix)
# ---------------------------------------------------------------------------

def test_serve_cache_len_prefix_aware():
    vlm = configs.get_reduced("internvl2-1b")           # vision_prefix=8
    assert serve_cache_len(vlm, 8, 4) == 8 + 8 + 4
    # encoder-decoder: audio frames live in enc_kv, NOT the decoder ring
    encdec = configs.get_reduced("whisper-small")
    assert serve_cache_len(encdec, 8, 3) == 8 + 3
    # sliding-window archs stay bounded by the window
    swa = configs.get_reduced("h2o-danube-1.8b")        # window=16
    assert serve_cache_len(swa, 30, 10) == 16


def test_engine_vision_prefix_ring_regression():
    """Prefill writes P + vision_prefix entries and decode advances from
    pos0 = P + prefix: with the old P+G sizing the pos-tagged ring silently
    overwrote the earliest context. The fixed ring retains position 0
    through the last decode step. (Explicitly the legacy ring engine —
    the paged parity suite lives in tests/test_kvcache.py.)"""
    cfg = dataclasses.replace(configs.get_reduced("internvl2-1b"),
                              w4a16_strategy="xla")
    P, G = 8, 6
    prefix = cfg.vision_prefix
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=1, max_prompt_len=P,
                        max_new_tokens=G, paged=False)
    assert eng.cache_len == P + prefix + G

    req = _requests(cfg, 1, P, G)[0]
    inputs = eng._prefill_inputs(req)
    logits, rstate = eng._prefill_fn(inputs)(eng.params, inputs)
    state = insert_slot(
        T.init_decode_state(cfg, 1, eng.cache_len), rstate, 0)
    valid = np.asarray(state["cache"]["kv"].pos[0, 0])
    assert sorted(valid[valid >= 0]) == list(range(P + prefix))

    serve = eng._serve_step()
    tok = jnp.argmax(logits[0])[None].astype(jnp.int32)
    for i in range(G - 1):
        pos = jnp.full((1,), P + prefix + i, jnp.int32)
        res = serve(eng.params, {"state": state, "tokens": tok, "pos": pos})
        tok, state = res["next"], res["state"]
    valid = np.asarray(state["cache"]["kv"].pos[0, 0])
    # every position 0 .. pos0+G-2 still present: nothing was overwritten
    assert sorted(valid[valid >= 0]) == list(range(P + prefix + G - 1))


def test_cache_reset_slots():
    cache = attention.init_cache(2, 4, 1, 8, jnp.float32)
    cache = attention.cache_insert(
        cache, jnp.ones((2, 1, 8)), jnp.ones((2, 1, 8)),
        jnp.zeros((2,), jnp.int32))
    out = attention.cache_reset_slots(cache, 1)
    assert int(out.pos[0, 0]) == 0                 # slot 0 untouched
    assert np.all(np.asarray(out.pos[1]) == -1)    # slot 1 wiped
    # layer-stacked form: batch is still the second-to-last pos dim
    stacked = attention.KVCache(
        k=jnp.zeros((3, 2, 4, 1, 8)), v=jnp.zeros((3, 2, 4, 1, 8)),
        pos=jnp.zeros((3, 2, 4), jnp.int32))
    out = attention.cache_reset_slots(stacked, 0)
    assert np.all(np.asarray(out.pos[:, 0]) == -1)
    assert np.all(np.asarray(out.pos[:, 1]) == 0)


# ---------------------------------------------------------------------------
# shard-local planning
# ---------------------------------------------------------------------------

def test_shard_problem_local_shapes():
    p = planning.MatmulProblem(M=4, N=256, K=512, group_size=128)
    mesh = FakeMesh({"data": 2, "model": 4})
    row = planning.shard_problem(p, mesh, "row")
    assert (row.M, row.N, row.K) == (2, 256, 128)      # K/tp, M/dp
    col = planning.shard_problem(p, mesh, "col")
    assert (col.M, col.N, col.K) == (2, 64, 512)       # N/tp, M/dp
    rep = planning.shard_problem(p, mesh, "rep")
    assert (rep.M, rep.N, rep.K) == (2, 256, 512)      # M/dp only
    # non-divisible dims stay global (mirror runtime/sharding.py rules)
    odd = planning.MatmulProblem(M=3, N=100, K=130, group_size=0)
    local = planning.shard_problem(odd, mesh, "row")
    assert (local.M, local.N, local.K) == (3, 100, 130)
    assert planning.shard_problem(p, None, "row") == p
    # batch divides GREEDILY per DP axis, exactly like batch_spec: M=4 on a
    # (pod=2, data=4) mesh shards over pod alone -> each rank runs M=2
    pod_mesh = FakeMesh({"pod": 2, "data": 4, "model": 1})
    local = planning.shard_problem(p, pod_mesh, "rep")
    assert local.M == 2


def test_plan_for_params_drops_ambiguous_square_keys():
    """wq (col) and wo (row) of a square attention projection share the
    global layer_key: when their shard-local plans disagree the key must be
    dropped (global-planner fallback) — never hand one layer the other's
    wrong-shape plan."""
    from repro.core.quant import quantize

    w = jax.random.normal(KEY, (1024, 1024), jnp.float32)
    qt = quantize(w, group_size=64)
    params = {"wq": {"kernel": qt}, "wo": {"kernel": qt}}
    mesh = FakeMesh({"data": 1, "model": 4})
    planning.PLAN_CACHE.clear()
    plans = planning.plan_for_params(params, M=1, mesh=mesh, backend="tpu")
    col = planning.plan_matmul(
        planning.shard_problem(
            planning.MatmulProblem(M=1, N=1024, K=1024, group_size=64,
                                   backend="tpu"), mesh, "col"),
        use_cache=False)
    row = planning.plan_matmul(
        planning.shard_problem(
            planning.MatmulProblem(M=1, N=1024, K=1024, group_size=64,
                                   backend="tpu"), mesh, "row"),
        use_cache=False)
    assert col != row, "test premise: local plans must actually disagree"
    assert "1024x1024" not in plans
    # non-ambiguous keys are unaffected
    rect = {"wq": {"kernel": quantize(
        jax.random.normal(KEY, (1024, 512), jnp.float32), group_size=64)}}
    plans = planning.plan_for_params(rect, M=1, mesh=mesh, backend="tpu")
    assert "1024x512" in plans
    planning.PLAN_CACHE.clear()


def test_plan_for_params_mesh_goes_shard_local():
    cfg = configs.get_reduced("h2o-danube-1.8b")
    params = _params(cfg)
    mesh = FakeMesh({"data": 2, "model": 4})
    planning.PLAN_CACHE.clear()
    plans = planning.plan_for_params(params, M=2, mesh=mesh)
    # returned dict keyed by GLOBAL layer shapes (what trace-time sees) ...
    assert "256x128" in plans and "128x256" in plans
    # ... while the plan-cache keys carry the per-rank LOCAL shapes
    keys = list(planning.PLAN_CACHE._plans)
    assert any(p.K == 64 and p.N == 128 and p.M == 1 for p in keys), \
        "row-parallel w_down (256x128 global) should cache as K/tp=64"
    assert any(p.K == 128 and p.N == 64 and p.M == 1 for p in keys), \
        "column-parallel w_up (128x256 global) should cache as N/tp=64"
    assert not any(p.K == 256 or p.N == 256 for p in keys), \
        "no global-shape problem should be costed under a TP mesh"
    planning.PLAN_CACHE.clear()


# ---------------------------------------------------------------------------
# scheduler / continuous batching
# ---------------------------------------------------------------------------

def test_engine_matches_manual_decode_loop():
    """Engine output (pooled slots, batched decode) is token-identical to a
    hand-rolled per-request prefill + decode loop — the pre-engine serve
    semantics."""
    # full expert capacity: MoE dropping is computed over the routing
    # batch, so the engine's padded chunk T would drop different tokens
    # than the T=P manual prefill (see prefill_chunk_step's MoE note)
    cfg = dataclasses.replace(configs.get_reduced("olmoe-1b-7b"),
                              w4a16_strategy="xla",
                              moe_capacity_factor=64.0)
    P, G, n = 8, 4, 2
    params = _params(cfg)
    reqs = _requests(cfg, n, P, G)
    eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                        max_new_tokens=G)
    report = eng.run(reqs)

    cache_len = serve_cache_len(cfg, P, G)
    prefill = jax.jit(rsteps.make_prefill_step(cfg, cache_len))
    serve = jax.jit(rsteps.make_serve_step(cfg))
    for req in reqs:
        inputs = {"tokens": jnp.asarray(req.prompt)[None]}
        logits, state = prefill(params, inputs)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        want = [int(tok[0])]
        for i in range(G - 1):
            pos = jnp.full((1,), P + i, jnp.int32)
            res = serve(params, {"state": state, "tokens": tok, "pos": pos})
            tok, state = res["next"], res["state"]
            want.append(int(tok[0]))
        assert report.results[req.rid] == want


def test_engine_continuous_batching_reuses_slots():
    cfg = dataclasses.replace(configs.get_reduced("olmoe-1b-7b"),
                              w4a16_strategy="xla")
    P, G, n = 8, 3, 5
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                        max_new_tokens=G)
    report = eng.run(_requests(cfg, n, P, G, arrival_every=1))
    assert sorted(report.results) == list(range(n))
    assert all(len(toks) == G for toks in report.results.values())
    assert len(report.latencies) == n
    # never more than the slot pool in flight; late arrivals admitted into
    # freed slots (continuous batching, not a static batch)
    assert max(r["active"] for r in report.step_records) <= 2
    assert any(r["admitted"] > 0 and r["step"] > 0
               for r in report.step_records)
    assert report.decode_tokens == sum(
        r["active"] for r in report.step_records)


def test_engine_rejects_oversized_requests():
    cfg = dataclasses.replace(configs.get_reduced("olmoe-1b-7b"),
                              w4a16_strategy="xla")
    eng = ServingEngine(cfg, _params(cfg), max_batch=1, max_prompt_len=4,
                        max_new_tokens=2)
    toolong = Request(rid=0, prompt=jnp.zeros((8,), jnp.int32),
                      max_new_tokens=2)
    with pytest.raises(ValueError, match="prompt length"):
        eng.run([toolong])
    greedy = Request(rid=0, prompt=jnp.zeros((4,), jnp.int32),
                     max_new_tokens=9)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.run([greedy])


# ---------------------------------------------------------------------------
# multi-device parity (subprocess with 8 fake CPU devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro import configs
from repro.kernels import planning
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.runtime.engine import Request, ServingEngine

out = {}
P, G, R, SLOTS = 8, 5, 3, 2


def build_requests(cfg, key):
    toks = jax.random.randint(key, (R, P), 0, cfg.vocab_size)
    reqs = []
    for i in range(R):
        kw = {}
        if cfg.vision_prefix:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(key, i),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        reqs.append(Request(rid=i, prompt=toks[i], max_new_tokens=G,
                            arrival_step=i, **kw))
    return reqs


def run_engine(cfg, params, mesh, reqs):
    eng = ServingEngine(cfg, params, mesh=mesh, max_batch=SLOTS,
                        max_prompt_len=P, max_new_tokens=G)
    rep = eng.run(reqs)
    return {str(k): v for k, v in sorted(rep.results.items())}, eng


for arch, meshes in [("h2o-danube-1.8b", [(2, 2), (1, 4)]),
                     ("internvl2-1b", [(2, 2)])]:
    cfg = configs.get_reduced(arch)          # w4a16_strategy="auto"
    key = jax.random.PRNGKey(0)
    params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
    reqs = build_requests(cfg, key)
    planning.PLAN_CACHE.clear()
    single, _ = run_engine(cfg, params, None, reqs)
    for dp, tp in meshes:
        planning.PLAN_CACHE.clear()
        mesh = make_local_mesh(data=dp, model=tp)
        sharded, eng = run_engine(cfg, params, mesh, reqs)
        tag = f"{arch}/{dp}x{tp}"
        out[tag + "/match"] = sharded == single
        # plan-cache keys must carry the per-rank local shapes:
        # w_down is (K=256, N=128) globally -> K/tp; w_up (128, 256) -> N/tp
        keys = list(planning.PLAN_CACHE._plans)
        out[tag + "/cache_local_row"] = any(
            p.K == 256 // tp and p.N == 128 for p in keys)
        out[tag + "/cache_local_col"] = any(
            p.K == 128 and p.N == 256 // tp for p in keys)
        out[tag + "/cache_no_global_K"] = not any(p.K == 256 for p in keys)
        out[tag + "/plans_keyed_global"] = (
            "256x128" in eng.plans and "128x256" in eng.plans)
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_engine_parity_and_local_plans():
    """TP=2/4 x DP engine decode is token-identical to single-device on two
    reduced archs (one vision-prefix), with plans keyed on shard-local
    shapes — the PR-4 acceptance demo."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out and all(out.values()), {k: v for k, v in out.items() if not v}
