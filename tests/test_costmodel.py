"""The paper's quantitative claims, reproduced by the calibrated Ascend model.

These are the EXPERIMENTS.md validation gates: if the model drifts away from
the paper's published numbers, these tests fail.
"""
import numpy as np

from repro.configs import PAPER_BATCH_SIZES, PAPER_GEMM_SHAPES
from repro.core import costmodel as cm


def sweep(fn):
    return np.array([[fn(M, N, K) for M in PAPER_BATCH_SIZES]
                     for (N, K) in PAPER_GEMM_SHAPES])


def test_fig2_splitk_speedup_range():
    """Paper §4.1: Split-K over data-parallel = 1.01×–1.74× and never a loss."""
    s = sweep(cm.splitk_speedup_ascend)
    assert s.min() >= 1.0 - 1e-9
    assert 1.5 <= s.max() <= 1.9, s.max()


def test_fig2_splitk_wins_when_k_much_larger_than_n():
    """Paper §4.1: 'when K is significantly larger than N, Split-K
    outperforms data-parallel approaches'."""
    gains_kgn, gains_other = [], []
    for (N, K) in PAPER_GEMM_SHAPES:
        for M in PAPER_BATCH_SIZES:
            g = cm.splitk_speedup_ascend(M, N, K)
            (gains_kgn if K >= 4 * N else gains_other).append(g)
    assert max(gains_kgn) > 1.3
    assert np.mean(gains_kgn) > np.mean(gains_other)


def test_fig3_w4a16_speedup_capped_at_1p48():
    """Paper §4.2 headline: max speedup over FP16 ≈ 1.48×, far below the
    theoretical ~4× — the decoupled-architecture memory bottleneck."""
    s = sweep(cm.w4a16_speedup_ascend)
    assert 1.40 <= s.max() <= 1.55, s.max()
    assert s.max() < 2.0            # nowhere near the naive 4x


def test_bottleneck_is_transfer_not_typecast():
    """Paper §4.2: removing the round-trip (bw_l2 → ∞) recovers most of the
    lost speedup; making the cast slower (cube_flops unchanged, vector time
    is hidden anyway) does not change it. I.e. the bottleneck is the
    transfer, not the dequant computation."""
    import dataclasses
    M, N, K = 16, 2048, 16384
    base = cm.w4a16_speedup_ascend(M, N, K)
    no_roundtrip = dataclasses.replace(cm.ASCEND, bw_l2=1e18)
    assert cm.w4a16_speedup_ascend(M, N, K, no_roundtrip) > base * 1.25


def test_tpu_fused_removes_roundtrip_penalty():
    """DESIGN.md adaptation claim: the fused TPU kernel approaches the 4×
    weight-traffic bound at small M; the decoupled port does not."""
    M, N, K = 1, 2048, 16384
    fp16 = cm.fp16_time_tpu(M, N, K)
    fused = cm.w4a16_time_tpu_fused(M, N, K)
    dec = cm.w4a16_time_tpu_decoupled(M, N, K)
    assert fp16 / fused > 3.0          # near the 4x bandwidth bound
    assert fp16 / dec < 1.0            # HBM round-trip makes it a LOSS on TPU
    assert fused < dec


def test_best_splitk_prefers_deep_k():
    assert cm.best_split_k_ascend(1, 1024, 16384) >= 2
    assert cm.best_split_k_ascend(2048, 8192, 1024) == 1
