"""Pallas flash-attention kernel vs the full-softmax oracle (interpret)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)

SWEEP = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window, dtype
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 256, 4, 1, 64, True, 64, jnp.float32),   # SWA + kv=1 GQA
    (2, 96, 96, 2, 2, 32, True, 0, jnp.bfloat16),     # unaligned S
    (1, 64, 192, 4, 4, 64, False, 0, jnp.float32),    # cross-attention
    (1, 128, 128, 8, 2, 128, True, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,D,causal,w,dt", SWEEP)
def test_flash_vs_oracle(B, Sq, Skv, Hq, Hkv, D, causal, w, dt):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), jnp.float32).astype(dt)
    got = flash_attention(q, k, v, causal=causal, window=w,
                          block_q=64, block_kv=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=w)
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_chunked_attention_matches_oracle():
    """The CPU/dry-run chunked path computes the same function."""
    from repro.models.attention import chunked_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 32), jnp.float32)
    for causal, w in [(True, 0), (True, 16), (False, 0)]:
        got = chunked_attention(q, k, v, causal=causal, window=w,
                                q_chunk=16, kv_chunk=16)
        want = ref.attention_ref(q, k, v, causal=causal, window=w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_model_with_flash_attention():
    """A model configured with attn_impl='flash' matches the chunked path."""
    from repro import configs
    from repro.models import transformer as T
    cfg = configs.get_reduced("h2o-danube-1.8b")
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    base = T.forward(params, cfg, toks)
    cfg_f = dataclasses.replace(cfg, attn_impl="flash")
    got = T.forward(params, cfg_f, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4)
