"""input_specs: every runnable cell produces well-formed abstract inputs."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs import SHAPES, input_specs, skip_reason


@pytest.mark.parametrize("arch", configs.ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_specs_shape_and_dtype(arch, shape_name):
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    if skip_reason(cfg, shape):
        pytest.skip("cell skipped by design")
    specs = input_specs(cfg, shape)
    leaves = jax.tree.leaves(specs)
    assert leaves, "no abstract inputs produced"
    for leaf in leaves:
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)

    if shape.kind == "train":
        toks = specs["batch"]["tokens"]
        assert toks.dtype == jnp.int32
        assert toks.shape[0] == shape.global_batch
        total = toks.shape[1] + (cfg.vision_prefix or 0)
        assert total == shape.seq_len
    elif shape.kind == "prefill":
        assert specs["tokens"].shape[0] == shape.global_batch
    else:
        assert specs["tokens"].shape == (shape.global_batch,)
        assert specs["pos"].shape == (shape.global_batch,)
        # SWA archs keep an O(window) cache even at 500k positions
        kv = jax.tree.leaves(specs["state"])
        biggest = max(l.size * l.dtype.itemsize for l in kv)
        if cfg.sliding_window and shape.name == "long_500k":
            assert biggest <= (cfg.num_layers * shape.global_batch
                               * cfg.sliding_window * cfg.kv_dim * 2 + 10)


def test_paper_gemm_shapes_listed():
    from repro.configs import PAPER_BATCH_SIZES, PAPER_GEMM_SHAPES
    assert len(PAPER_GEMM_SHAPES) == 8 and len(PAPER_BATCH_SIZES) == 5
