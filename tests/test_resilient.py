"""Fault-tolerant runner: retry, checkpoint/restart, elastic re-mesh hook."""
import jax
import jax.numpy as jnp

from repro.runtime.resilient import RunnerConfig, run_training


def make_setup():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    opt = {"m": jnp.zeros((4,), jnp.float32)}

    def train_step(params, opt, inputs):
        p = {"w": params["w"] + 1.0}
        return p, opt, {"loss": jnp.sum(p["w"])}

    def batches(step):
        return {"step": step}

    return params, opt, train_step, batches


def test_transient_failures_are_retried(tmp_path):
    params, opt, step_fn, batches = make_setup()
    boom = {"left": 2}

    def inject(step, retries):
        if step == 3 and boom["left"] > 0:
            boom["left"] -= 1
            return True
        return False

    p, o, hist = run_training(
        cfg=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_retries=3),
        train_step=step_fn, params=params, opt_state=opt,
        batches=batches, num_steps=6, inject_failure=inject)
    kinds = [h[0] for h in hist]
    assert kinds.count("failure") == 2 and "restart" not in kinds
    assert float(p["w"][0]) == 6.0            # every step applied exactly once


def test_hard_failure_restores_checkpoint_and_remeshes(tmp_path):
    params, opt, step_fn, batches = make_setup()

    def inject(step, retries):
        return step == 4          # permanently failing step

    remeshed = {"n": 0}

    def remesh():
        remeshed["n"] += 1

        def healed_step(params, opt, inputs):   # re-lowered on survivors
            p = {"w": params["w"] + 1.0}
            return p, opt, {"loss": jnp.sum(p["w"])}
        return healed_step

    calls = {"n": 0}

    def inject_once(step, retries):
        if step == 4 and calls["n"] < 4:
            calls["n"] += 1
            return True
        return False

    p, o, hist = run_training(
        cfg=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=1, max_retries=3),
        train_step=step_fn, params=params, opt_state=opt,
        batches=batches, num_steps=8, inject_failure=inject_once,
        remesh_fn=remesh)
    kinds = [h[0] for h in hist]
    assert "restart" in kinds and remeshed["n"] == 1
    assert float(p["w"][0]) == 8.0            # resumed + completed all steps


def test_resume_from_existing_checkpoint(tmp_path):
    params, opt, step_fn, batches = make_setup()
    p, o, hist = run_training(
        cfg=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2),
        train_step=step_fn, params=params, opt_state=opt,
        batches=batches, num_steps=5)
    # fresh process resumes from step 5's checkpoint
    p2, o2, hist2 = run_training(
        cfg=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2),
        train_step=step_fn, params=params, opt_state=opt,
        batches=batches, num_steps=8)
    assert hist2[0][0] == "resume"
    assert float(p2["w"][0]) == 8.0
