"""Stage-template invariants + the strategy × format × edge-shape parity
matrix (ISSUE 3): every registered strategy, on every format it supports,
at the shapes that historically break tiled kernels — M not a multiple of
SUBLANE, K == group_size (a single scale group), and N == LANE — checked
against the format's reference oracle within analytic quantization bounds
(same quantized operands → only fp32 association differs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import (
    get_format, per_channel_scales, quantize, w4a8_matmul_ref,
)
from repro.kernels import common, planning, ref, template
from repro.kernels.planning import KernelPlan, MatmulProblem

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# parity matrix
# ---------------------------------------------------------------------------

FORMATS = ("w4a16_g128", "w8a16_channel", "w4a8_g128")

EDGE_SHAPES = [
    # M, K, N — g=128 where the format is grouped (channel formats span K)
    (5, 256, 384),                    # M not a multiple of SUBLANE
    (8, 128, 256),                    # K == group_size: a single scale group
    (16, 256, common.LANE),           # N == LANE: one lane-wide block column
    (3, 128, common.LANE),            # all three edges at once
]


def _oracle(fmt_name, x, qt):
    if get_format(fmt_name).quantized_activations:
        return w4a8_matmul_ref(x, qt)           # same activation quant path
    return ref.w4a16_ref(x, qt)                 # float-activation formats


def _cases():
    for fmt in FORMATS:
        for strategy in planning.strategies_for_format(fmt):
            for shape in EDGE_SHAPES:
                yield fmt, strategy, shape


@pytest.mark.parametrize("fmt,strategy,shape", list(_cases()),
                         ids=lambda v: str(v))
def test_parity_matrix(fmt, strategy, shape):
    M, K, N = shape
    k1, k2 = jax.random.split(KEY)
    w = jax.random.normal(k1, (K, N), jnp.float32)
    x = jax.random.normal(k2, (M, K), jnp.float32)
    qt = quantize(w, fmt)
    problem = MatmulProblem.from_operands(x, qt)
    strat = planning.get_strategy(strategy)
    if not strat.supports(problem):
        pytest.skip(f"{strategy} rejects {shape}")
    plan = planning.plan_matmul(problem, strategy=strategy, use_cache=False)
    got = np.asarray(planning.execute(plan, x, qt, interpret=True),
                     np.float32)
    want = np.asarray(_oracle(fmt, x, qt), np.float32)
    # same quantized operands: any difference is fp32 summation order,
    # bounded well below one rounding step of the quantization grid (s/2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3,
                               err_msg=f"{fmt}/{strategy}/{shape}")


def test_new_fused_kernels_are_registered_planner_strategies():
    """Acceptance: w8a16_fused / w4a8_fused are planner strategies the cost
    model actually picks on the target backend."""
    names = planning.available_strategies()
    assert "w8a16_fused" in names and "w4a8_fused" in names
    pick8 = planning.plan_matmul(
        MatmulProblem(M=16, N=1024, K=4096, group_size=4096, backend="tpu",
                      format="w8a16_channel"), use_cache=False)
    assert pick8.strategy == "w8a16_fused"
    pick48 = planning.plan_matmul(
        MatmulProblem(M=16, N=1024, K=4096, group_size=128, backend="tpu",
                      format="w4a8_g128"), use_cache=False)
    assert pick48.strategy == "w4a8_fused"
    # off-TPU the interpret penalty keeps the planner on the XLA paths
    cpu48 = planning.plan_matmul(
        MatmulProblem(M=16, N=1024, K=4096, group_size=128, backend="cpu",
                      format="w4a8_g128"), use_cache=False)
    assert cpu48.strategy == "w4a8_xla"


def test_planner_assigns_split_k_to_new_tiled_strategies():
    """Splittability is a Strategy attribute, not a name list: the planner
    fills split_k for w4a8_fused in the decode regime (M=1, K ≫ N) exactly
    as it does for the w4a16 kernels."""
    plan = planning.plan_matmul(
        MatmulProblem(M=1, N=128, K=16384, group_size=128, backend="tpu",
                      format="w4a8_g128"),
        strategy="w4a8_fused", use_cache=False)
    assert plan.split_k > 1
    # XLA paths never get a split
    assert planning.get_strategy("w4a8_xla").splittable is False


def test_forced_split_k_paths_agree():
    """Split-K invariance holds for the new kernels too (paper Alg. 1)."""
    from repro.kernels.w4a8_fused import w4a8_fused
    from repro.kernels.w8a16_fused import w8a16_fused
    k1, k2 = jax.random.split(KEY)
    w = jax.random.normal(k1, (512, 256), jnp.float32)
    x = jax.random.normal(k2, (4, 512), jnp.float32)
    qt8 = quantize(w, "w8a16_channel")
    base = w8a16_fused(x, qt8, split_k=1, interpret=True)
    np.testing.assert_allclose(
        np.asarray(w8a16_fused(x, qt8, split_k=2, interpret=True)),
        np.asarray(base), rtol=1e-5, atol=1e-4)
    qt48 = quantize(w, "w4a8_g128")
    base = w4a8_fused(x, qt48, split_k=1, interpret=True)
    np.testing.assert_allclose(
        np.asarray(w4a8_fused(x, qt48, split_k=2, interpret=True)),
        np.asarray(base), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# block chooser: divisibility + the VMEM budget is enforced at launch time
# ---------------------------------------------------------------------------

def test_choose_blocks_divides_and_group_aligns():
    bc = template.choose_blocks(128, 1024, 4096, group_size=128,
                                weight_elt_bytes=0.5, has_scales=True,
                                dequant_tile=True)
    assert 128 % bc.bm == 0 and 1024 % bc.bn == 0
    assert (4096 // bc.split_k) % bc.bk == 0
    assert bc.bk % 128 == 0 or 128 % bc.bk == 0
    assert bc.nk == (4096 // bc.split_k) // bc.bk


def test_choose_blocks_enforces_vmem_budget():
    """A tiny budget shrinks bk (then bn) until the working set fits —
    the satellite: kernels enforce the budget, not only the autotuner."""
    budget = 2 * 1024 * 1024
    bc = template.choose_blocks(
        128, 1024, 4096, group_size=128, weight_elt_bytes=0.5,
        has_scales=True, dequant_tile=True, vmem_budget=budget)
    assert common.vmem_working_set(
        bc.bm, bc.bn, bc.bk, 128, weight_elt_bytes=0.5) <= budget
    # and the default-budget choice is unchanged from the target blocks
    bc_def = template.choose_blocks(128, 1024, 4096, group_size=128,
                                    weight_elt_bytes=0.5, has_scales=True,
                                    dequant_tile=True)
    assert (bc_def.bm, bc_def.bn, bc_def.bk) == (128, 256, 512)


def test_choose_blocks_refuses_misaligned_splits():
    with pytest.raises(ValueError, match="group-aligned"):
        template.choose_blocks(8, 256, 512, group_size=128, split_k=8)
    with pytest.raises(ValueError, match="divide K"):
        template.choose_blocks(8, 256, 512, split_k=3)


def test_budget_constrained_kernel_still_correct():
    """tiled_matmul under an artificially tiny budget picks smaller blocks
    and still matches the oracle."""
    k1, k2 = jax.random.split(KEY)
    w = jax.random.normal(k1, (512, 256), jnp.float32)
    x = jax.random.normal(k2, (8, 512), jnp.float32)
    qt = quantize(w, group_size=128)
    got = template.tiled_matmul(
        x,
        template.GroupedInt4Dequant(qt.packed, qt.scales, qt.zeros),
        template.FloatContraction(),
        N=qt.N, group_size=qt.group_size,
        vmem_budget=512 * 1024, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.w4a16_ref(x, qt)),
                               rtol=1e-5, atol=1e-4)


def test_gemm_block_chooser_handles_unaligned_m():
    """The dead/duplicated bm computation in the old gemm() is gone: padded
    M routes through the shared chooser and stays correct for any M."""
    from repro.kernels.gemm import gemm
    for M in (1, 5, 8, 33):
        k1, k2 = jax.random.split(KEY)
        x = jax.random.normal(k1, (M, 256), jnp.float32)
        w = jax.random.normal(k2, (256, 128), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(gemm(x, w, interpret=True)),
            np.asarray(ref.gemm_ref(x, w)), rtol=1e-5, atol=1e-4)


def test_per_channel_scales_helper():
    w = jax.random.normal(KEY, (64, 32), jnp.float32)
    qt = quantize(w, "w8a16_channel")
    s, z = per_channel_scales(qt)
    assert s.shape == (1, 32) and z is None
    with pytest.raises(ValueError, match="group-granular"):
        per_channel_scales(quantize(w, group_size=32))


def test_plan_roundtrip_for_new_strategies():
    """Plans for the new strategies JSON round-trip (cache compatibility)."""
    for name in ("w8a16_fused", "w4a8_fused"):
        plan = KernelPlan(strategy=name, split_k=2)
        assert KernelPlan.from_json(plan.to_json()) == plan
