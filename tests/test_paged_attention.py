"""Fused paged-attention decode kernel tests: op-level parity with the
XLA gather path (both KV formats, windowed and full attention, every
Split-K partition degree), the gather_window fp16 fast path, the
attention-path planner, and engine-level token parity across SWA-wrap /
vision-prefix / shared-prefix-CoW archs — single-device and TP×DP on 8
fake devices (subprocess)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import quant
from repro.kernels import common, planning
from repro.kernels.paged_attention import fused_paged_attention, kv_stage_for
from repro.kernels import template
from repro.models import transformer as T
from repro.runtime import kvcache as kvc
from repro.runtime import metrics as rmetrics
from repro.runtime.engine import Request, ServingEngine

ROOT = os.path.join(os.path.dirname(__file__), "..")
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# op-level parity: fused kernel ≡ gather + decode_attention
# ---------------------------------------------------------------------------

def _filled_pool(fmt_name, *, B=2, Hkv=2, D=32, ps=4, T_pages=4, fill=14,
                 wrap_from=0):
    """A pool with ``fill`` tokens scattered per slot through the public
    insert path. ``wrap_from > 0`` writes positions [wrap_from, wrap_from +
    fill) into a T_pages·ps ring — the SWA wrap layout where logical
    offsets alias ``pos % cache_len``."""
    fmt = quant.get_kv_format(fmt_name)
    nb = 1 + B * T_pages
    cache_len = T_pages * ps
    pool = kvc.init_pool(nb, ps, Hkv, D, jnp.float32, fmt_name)
    tables = jnp.asarray(
        (1 + np.arange(B * T_pages, dtype=np.int32)).reshape(B, T_pages))
    for p in range(wrap_from, wrap_from + fill):
        k = jax.random.normal(jax.random.fold_in(KEY, 2 * p),
                              (B, Hkv, D), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(KEY, 2 * p + 1),
                              (B, Hkv, D), jnp.float32)
        pool = kvc.paged_insert(pool, tables, k, v,
                                jnp.full((B,), p, jnp.int32),
                                cache_len=cache_len, fmt=fmt)
    pos = jnp.full((B,), wrap_from + fill - 1, jnp.int32)
    q = jax.random.normal(jax.random.fold_in(KEY, 999),
                          (B, 2 * Hkv, D), jnp.float32)
    return q, pool, tables, pos, fmt


@pytest.mark.parametrize("fmt_name", ["kv_fp16", "kv8_channel"])
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("parts", [1, 2, 4])
def test_fused_matches_gather(fmt_name, window, parts):
    q, pool, tables, pos, fmt = _filled_pool(fmt_name)
    ref = kvc.paged_decode_attention(q, pool, tables, pos, window=window,
                                     fmt=fmt, out_dtype=jnp.float32)
    out = fused_paged_attention(q, pool, tables, pos, window=window,
                                fmt=fmt, out_dtype=jnp.float32,
                                kv_partitions=parts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_fused_matches_gather_wrapped_ring():
    """SWA wrap: positions past cache_len alias earlier ring offsets, so
    pages hold out-of-order position tags — masking must follow the tags,
    not the page order."""
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16", wrap_from=9)
    for window in (0, 8):
        ref = kvc.paged_decode_attention(q, pool, tables, pos,
                                         window=window, fmt=fmt,
                                         out_dtype=jnp.float32)
        out = fused_paged_attention(q, pool, tables, pos, window=window,
                                    fmt=fmt, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)


def test_fused_unmapped_tables_mask_to_null_block():
    """-1 table entries resolve to the null block (all -1 tags): parity
    holds when slots hold windows of different lengths."""
    q, pool, tables, pos, fmt = _filled_pool("kv8_channel", fill=6)
    tables = tables.at[1, 2:].set(-1)      # slot 1: half the table unmapped
    ref = kvc.paged_decode_attention(q, pool, tables, pos, fmt=fmt,
                                     out_dtype=jnp.float32)
    out = fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                                out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_fused_partition_count_validation():
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")   # T=4 pages
    with pytest.raises(ValueError, match="must divide"):
        fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                              out_dtype=jnp.float32, kv_partitions=3)


def test_fused_interpret_toggle():
    """The CPU-CI fallback: interpret=None resolves per-backend (True on
    CPU), and forcing interpret=True gives the same tokens — the toggle
    the parity suite rides."""
    assert common.resolve_interpret(None) is common.is_cpu()
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    auto = fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                                 out_dtype=jnp.float32)
    forced = fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                                   out_dtype=jnp.float32, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))


def test_kv_stage_selection_and_refusal():
    _, pool, _, _, _ = _filled_pool("kv_fp16")
    assert isinstance(kv_stage_for(pool, quant.get_kv_format("kv_fp16")),
                      template.DensePages)
    _, qpool, _, _, _ = _filled_pool("kv8_channel")
    assert isinstance(kv_stage_for(qpool, quant.get_kv_format("kv8_channel")),
                      template.Int8ChannelPages)
    # a quantized format over a scale-less pool is refused loudly
    with pytest.raises(ValueError, match="scales"):
        kv_stage_for(pool, quant.get_kv_format("kv8_channel"))


# ---------------------------------------------------------------------------
# gather_window fp16 fast path (satellite)
# ---------------------------------------------------------------------------

def test_gather_window_fp16_skips_dequant(monkeypatch):
    """Passthrough pools must not route through kv_dequantize (no dequant
    pass, no scale gathers) — the pre-fix behavior cost an extra pool-sized
    elementwise pass per decode step."""
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    want = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32)

    def boom(*a, **k):
        raise AssertionError("kv_dequantize called for a passthrough format")

    monkeypatch.setattr(kvc, "kv_dequantize", boom)
    got = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got.k), np.asarray(want.k))
    np.testing.assert_array_equal(np.asarray(got.pos), np.asarray(want.pos))
    # quantized pools still dequantize
    q2, qpool, t2, p2, qfmt = _filled_pool("kv8_channel")
    with pytest.raises(AssertionError, match="passthrough"):
        kvc.gather_window(qpool, t2, fmt=qfmt, out_dtype=jnp.float32)


def test_gather_window_fp16_dtype_cast():
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    win = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.bfloat16)
    assert win.k.dtype == jnp.bfloat16 and win.v.dtype == jnp.bfloat16


def test_paged_decode_attention_rejects_unknown_path():
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    with pytest.raises(ValueError, match="unknown attn_path"):
        kvc.paged_decode_attention(q, pool, tables, pos, fmt=fmt,
                                   out_dtype=jnp.float32, attn_path="ring")


# ---------------------------------------------------------------------------
# planner: ring vs gather vs fused as a costed decision
# ---------------------------------------------------------------------------

def _problem(**kw):
    base = dict(B=4, Hq=32, Hkv=8, D=128, cache_len=4096, page_size=16,
                kv_format="kv8_channel", paged=True, backend="tpu")
    base.update(kw)
    return planning.AttentionProblem(**base)


def test_plan_attention_backend_split():
    """The acceptance decision: fused wins on TPU for long-context paged
    decode (one trip over the pool); the interpret penalty keeps the XLA
    gather in front on CPU hosts."""
    assert planning.plan_attention(_problem()).path == "fused"
    assert planning.plan_attention(_problem(kv_format="kv_fp16")).path \
        == "fused"
    assert planning.plan_attention(_problem(backend="cpu")).path == "gather"
    # non-paged engines only have the ring layout
    assert planning.plan_attention(
        _problem(paged=False, kv_format="kv_fp16")).path == "ring"


def test_plan_attention_costs_charge_gather_roundtrip():
    """The roofline entries price the gather's HBM round-trip: on TPU the
    gather path is strictly more bytes (and time) than fused for the same
    problem, and the gap grows with context."""
    from repro.core import costmodel as cm
    for ctx in (1024, 4096, 16384):
        gb = cm.paged_attn_bytes("gather", 4, 32, 8, 128, ctx,
                                 quantized=True)
        fb = cm.paged_attn_bytes("fused", 4, 32, 8, 128, ctx,
                                 quantized=True, kv_partitions=8)
        assert fb < gb
        assert cm.attn_decode_time_tpu("fused", 4, 32, 8, 128, ctx,
                                       quantized=True, kv_partitions=8) < \
            cm.attn_decode_time_tpu("gather", 4, 32, 8, 128, ctx,
                                    quantized=True)


def test_plan_attention_forced_path_validation():
    with pytest.raises(ValueError, match="unknown attention path"):
        planning.plan_attention(_problem(), path="flash3")
    with pytest.raises(ValueError, match="does not support"):
        planning.plan_attention(_problem(), path="ring")      # paged
    with pytest.raises(ValueError, match="does not support"):
        planning.plan_attention(_problem(paged=False), path="fused")
    plan = planning.plan_attention(_problem(backend="cpu"), path="fused")
    assert plan.path == "fused"            # forcing beats the cost ranking


def test_choose_kv_partitions_occupancy():
    cores = planning.num_cores()
    # grid already full → no split
    assert planning.choose_kv_partitions(cores, 1, 64) == 1
    # underfilled grid → split up to the core count, power-of-2 divisor
    s = planning.choose_kv_partitions(1, 1, 64)
    assert s >= 1 and 64 % s == 0 and (s & (s - 1)) == 0
    if cores >= 2:
        assert s > 1
    # never more partitions than pages
    assert planning.choose_kv_partitions(1, 1, 1) == 1


# ---------------------------------------------------------------------------
# engine-level token parity: fused ≡ gather across archs × formats
# ---------------------------------------------------------------------------

def _params(cfg, quantized=True):
    p = T.init_params(KEY, cfg)
    return T.quantize_params(p, cfg, min_size=0) if quantized else p


def _requests(cfg, n, P, G, *, same_prompt=False):
    toks = jax.random.randint(KEY, (n, P), 0, cfg.vocab_size)
    reqs = []
    for i in range(n):
        kw = {}
        if cfg.vision_prefix:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, 0 if same_prompt else i),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        reqs.append(Request(rid=i, prompt=toks[0] if same_prompt else toks[i],
                            max_new_tokens=G, **kw))
    return reqs


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "internvl2-1b"])
@pytest.mark.parametrize("kv_format", ["kv_fp16", "kv8_channel"])
def test_fused_engine_parity(arch, kv_format):
    """Fused-paged decode is token-identical to gather decode on the SWA
    (ring-wrap) and vision-prefix archs, both KV formats — the tentpole
    acceptance. Prompts run past the danube window so pages wrap."""
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="xla")
    P, G, n = 12, 6, 2
    params = _params(cfg)

    def run(path):
        eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                            max_new_tokens=G, page_size=4,
                            kv_format=kv_format, attn_path=path)
        assert eng.attn_path == path
        return eng.run(_requests(cfg, n, P, G)).results

    got, want = run("fused"), run("gather")
    assert got == want and sorted(got) == list(range(n))


def test_fused_engine_parity_shared_prefix_cow():
    """Shared-prefix CoW arch case: identical prompts alias prompt pages
    until the divergent decode write copies them — the fused walk reads
    the exact same physical pages the gather path does."""
    cfg = dataclasses.replace(configs.get_reduced("internvl2-1b"),
                              w4a16_strategy="xla")
    P, G, n = 8, 4, 2
    params = _params(cfg)

    def run(path):
        eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                            max_new_tokens=G, page_size=4, attn_path=path)
        rep = eng.run(_requests(cfg, n, P, G, same_prompt=True))
        return rep.results, rep.peak_pages

    got, pages_f = run("fused")
    want, pages_g = run("gather")
    assert got == want
    assert got[0] == got[1]                 # same prompt → same greedy run
    assert pages_f == pages_g               # identical allocator behavior


def test_engine_attn_path_resolution_and_metrics():
    """auto resolves per backend (gather on CPU CI), the resolved path is
    exported as a /metrics gauge + per-path step counter, and fused on a
    non-paged engine is refused loudly."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                        max_new_tokens=3, page_size=4)
    assert eng.attn_path == ("fused" if jax.default_backend() == "tpu"
                             else "gather")
    eng.metrics = rmetrics.MetricsRegistry()
    eng.run(_requests(cfg, 2, 8, 3))
    text = eng.metrics.render()
    assert f"engine_attn_path {float(1 if eng.attn_path == 'gather' else 2)}" \
        in text.replace(".0", "") or "engine_attn_path" in text
    assert f"engine_attn_path_steps_{eng.attn_path}" in text
    with pytest.raises(ValueError, match="does not support"):
        ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                      max_new_tokens=3, paged=False, attn_path="fused")
    ring = ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                         max_new_tokens=3, paged=False)
    assert ring.attn_path == "ring"


# ---------------------------------------------------------------------------
# multi-device parity (subprocess with 8 fake CPU devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax

from repro import configs
from repro.kernels import planning
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.runtime.engine import Request, ServingEngine

out = {}
P, G, R, SLOTS = 8, 4, 2, 2
arch = "h2o-danube-1.8b"
cfg = configs.get_reduced(arch)
key = jax.random.PRNGKey(0)
params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
toks = jax.random.randint(key, (R, P), 0, cfg.vocab_size)


def run_engine(mesh, attn_path):
    planning.PLAN_CACHE.clear()
    eng = ServingEngine(cfg, params, mesh=mesh, max_batch=SLOTS,
                        max_prompt_len=P, max_new_tokens=G, page_size=4,
                        attn_path=attn_path)
    reqs = [Request(rid=i, prompt=toks[i], max_new_tokens=G)
            for i in range(R)]
    return {str(k): v for k, v in sorted(eng.run(reqs).results.items())}


single_gather = run_engine(None, "gather")
single_fused = run_engine(None, "fused")
out["single/fused==gather"] = single_fused == single_gather
mesh = make_local_mesh(data=2, model=4)
sharded_fused = run_engine(mesh, "fused")
out["tp4xdp2/fused==single"] = sharded_fused == single_gather
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_fused_engine_parity():
    """Forced-fused decode on a TP=4 x DP=2 mesh (8 fake CPU devices) is
    token-identical to single-device gather decode."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out and all(out.values()), {k: v for k, v in out.items() if not v}
