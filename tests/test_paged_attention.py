"""Fused paged-attention decode kernel tests: op-level parity with the
XLA gather path (both KV formats, windowed and full attention, every
Split-K partition degree), the gather_window fp16 fast path, the
attention-path planner, and engine-level token parity across SWA-wrap /
vision-prefix / shared-prefix-CoW archs — single-device and TP×DP on 8
fake devices (subprocess)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import quant
from repro.kernels import common, planning
from repro.kernels.paged_attention import (
    fused_chunk_attention, fused_paged_attention, kv_stage_for)
from repro.kernels import template
from repro.models import attention, transformer as T
from repro.runtime import kvcache as kvc
from repro.runtime import metrics as rmetrics
from repro.runtime.engine import Request, ServingEngine

ROOT = os.path.join(os.path.dirname(__file__), "..")
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# op-level parity: fused kernel ≡ gather + decode_attention
# ---------------------------------------------------------------------------

def _filled_pool(fmt_name, *, B=2, Hkv=2, D=32, ps=4, T_pages=4, fill=14,
                 wrap_from=0):
    """A pool with ``fill`` tokens scattered per slot through the public
    insert path. ``wrap_from > 0`` writes positions [wrap_from, wrap_from +
    fill) into a T_pages·ps ring — the SWA wrap layout where logical
    offsets alias ``pos % cache_len``."""
    fmt = quant.get_kv_format(fmt_name)
    nb = 1 + B * T_pages
    cache_len = T_pages * ps
    pool = kvc.init_pool(nb, ps, Hkv, D, jnp.float32, fmt_name)
    tables = jnp.asarray(
        (1 + np.arange(B * T_pages, dtype=np.int32)).reshape(B, T_pages))
    for p in range(wrap_from, wrap_from + fill):
        k = jax.random.normal(jax.random.fold_in(KEY, 2 * p),
                              (B, Hkv, D), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(KEY, 2 * p + 1),
                              (B, Hkv, D), jnp.float32)
        pool = kvc.paged_insert(pool, tables, k, v,
                                jnp.full((B,), p, jnp.int32),
                                cache_len=cache_len, fmt=fmt)
    pos = jnp.full((B,), wrap_from + fill - 1, jnp.int32)
    q = jax.random.normal(jax.random.fold_in(KEY, 999),
                          (B, 2 * Hkv, D), jnp.float32)
    return q, pool, tables, pos, fmt


@pytest.mark.parametrize("fmt_name", ["kv_fp16", "kv8_channel"])
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("parts", [1, 2, 4])
def test_fused_matches_gather(fmt_name, window, parts):
    q, pool, tables, pos, fmt = _filled_pool(fmt_name)
    ref = kvc.paged_decode_attention(q, pool, tables, pos, window=window,
                                     fmt=fmt, out_dtype=jnp.float32)
    out = fused_paged_attention(q, pool, tables, pos, window=window,
                                fmt=fmt, out_dtype=jnp.float32,
                                kv_partitions=parts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_fused_matches_gather_wrapped_ring():
    """SWA wrap: positions past cache_len alias earlier ring offsets, so
    pages hold out-of-order position tags — masking must follow the tags,
    not the page order."""
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16", wrap_from=9)
    for window in (0, 8):
        ref = kvc.paged_decode_attention(q, pool, tables, pos,
                                         window=window, fmt=fmt,
                                         out_dtype=jnp.float32)
        out = fused_paged_attention(q, pool, tables, pos, window=window,
                                    fmt=fmt, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)


def test_fused_unmapped_tables_mask_to_null_block():
    """-1 table entries resolve to the null block (all -1 tags): parity
    holds when slots hold windows of different lengths."""
    q, pool, tables, pos, fmt = _filled_pool("kv8_channel", fill=6)
    tables = tables.at[1, 2:].set(-1)      # slot 1: half the table unmapped
    ref = kvc.paged_decode_attention(q, pool, tables, pos, fmt=fmt,
                                     out_dtype=jnp.float32)
    out = fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                                out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_fused_partition_count_validation():
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")   # T=4 pages
    with pytest.raises(ValueError, match="must divide"):
        fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                              out_dtype=jnp.float32, kv_partitions=3)


def test_fused_interpret_toggle():
    """The CPU-CI fallback: interpret=None resolves per-backend (True on
    CPU), and forcing interpret=True gives the same tokens — the toggle
    the parity suite rides."""
    assert common.resolve_interpret(None) is common.is_cpu()
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    auto = fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                                 out_dtype=jnp.float32)
    forced = fused_paged_attention(q, pool, tables, pos, fmt=fmt,
                                   out_dtype=jnp.float32, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))


def test_kv_stage_selection_and_refusal():
    _, pool, _, _, _ = _filled_pool("kv_fp16")
    assert isinstance(kv_stage_for(pool, quant.get_kv_format("kv_fp16")),
                      template.DensePages)
    _, qpool, _, _, _ = _filled_pool("kv8_channel")
    assert isinstance(kv_stage_for(qpool, quant.get_kv_format("kv8_channel")),
                      template.Int8ChannelPages)
    # a quantized format over a scale-less pool is refused loudly
    with pytest.raises(ValueError, match="scales"):
        kv_stage_for(pool, quant.get_kv_format("kv8_channel"))


# ---------------------------------------------------------------------------
# op-level multi-query parity: fused_chunk_attention ≡ gather + segment
# ---------------------------------------------------------------------------

def _roundtrip(x, fmt):
    return quant.kv_dequantize(*quant.kv_quantize(x, fmt), fmt=fmt,
                               dtype=jnp.float32)


def _chunk_setup(fmt_name, *, B=2, C=3, start=6, Hkv=2, D=32, ps=4,
                 T_pages=4):
    """A pool holding positions [0, start) per slot plus an in-flight
    chunk of C tokens at positions [start, start+C) — the pre-scatter
    state both chunk-attention paths see. Positions past cache_len alias
    earlier ring offsets (the SWA-wrap layout)."""
    fmt = quant.get_kv_format(fmt_name)
    nb = 1 + B * T_pages
    cache_len = T_pages * ps
    pool = kvc.init_pool(nb, ps, Hkv, D, jnp.float32, fmt_name)
    tables = jnp.asarray(
        (1 + np.arange(B * T_pages, dtype=np.int32)).reshape(B, T_pages))
    for p in range(start):
        k = jax.random.normal(jax.random.fold_in(KEY, 2 * p),
                              (B, Hkv, D), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(KEY, 2 * p + 1),
                              (B, Hkv, D), jnp.float32)
        pool = kvc.paged_insert(pool, tables, k, v,
                                jnp.full((B,), p, jnp.int32),
                                cache_len=cache_len, fmt=fmt)
    q = jax.random.normal(jax.random.fold_in(KEY, 777),
                          (B, C, 2 * Hkv, D), jnp.float32)
    # the chunk segment takes the same quantize round-trip the model
    # applies before attending it (a no-op for kv_fp16)
    kseg = _roundtrip(jax.random.normal(jax.random.fold_in(KEY, 778),
                                        (B, C, Hkv, D), jnp.float32), fmt)
    vseg = _roundtrip(jax.random.normal(jax.random.fold_in(KEY, 779),
                                        (B, C, Hkv, D), jnp.float32), fmt)
    positions = jnp.broadcast_to(
        start + jnp.arange(C, dtype=jnp.int32), (B, C))
    return q, kseg, vseg, pool, tables, positions, fmt


def _chunk_reference(q, kseg, vseg, pool, tables, positions, *, window,
                     fmt):
    """The gather path verbatim (transformer._paged_chunk_attn gather
    branch): materialize the window, mask entries at chunk positions,
    concatenate the segment, run prefix_chunk_attention."""
    win = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32)
    start = positions[:, :1]
    wpos = jnp.where(win.pos < start, win.pos, -1)
    seq = attention.KVCache(
        k=jnp.concatenate([win.k, kseg.astype(win.k.dtype)], axis=1),
        v=jnp.concatenate([win.v, vseg.astype(win.v.dtype)], axis=1),
        pos=jnp.concatenate([wpos, positions], axis=1))
    return attention.prefix_chunk_attention(q, seq, positions,
                                            window=window)


@pytest.mark.parametrize("fmt_name", ["kv_fp16", "kv8_channel"])
@pytest.mark.parametrize("C,start", [(1, 6), (3, 6), (6, 5)])
@pytest.mark.parametrize("window", [0, 8])
def test_fused_chunk_matches_gather(fmt_name, C, start, window):
    """The tentpole parity matrix: q_len ∈ {1, 3, page-straddling 6},
    both KV formats, full + sliding-window masks — the fused multi-query
    walk must reproduce the gathered-window reference bit-for-policy."""
    q, ks, vs, pool, tables, positions, fmt = _chunk_setup(
        fmt_name, C=C, start=start)
    ref = _chunk_reference(q, ks, vs, pool, tables, positions,
                           window=window, fmt=fmt)
    out = fused_chunk_attention(q, ks, vs, pool, tables, positions,
                                window=window, fmt=fmt,
                                out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("parts", [1, 2])
def test_fused_chunk_split_k(parts):
    q, ks, vs, pool, tables, positions, fmt = _chunk_setup(
        "kv8_channel", C=3, start=9)
    ref = _chunk_reference(q, ks, vs, pool, tables, positions,
                           window=0, fmt=fmt)
    out = fused_chunk_attention(q, ks, vs, pool, tables, positions,
                                window=0, fmt=fmt, out_dtype=jnp.float32,
                                kv_partitions=parts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_fused_chunk_swa_wrap():
    """Chunk positions past cache_len: the pool's pos tags are
    out-of-order across pages and stale single-counted entries at chunk
    positions must stay masked — the layout chunked prefill hits on SWA
    archs whose prompt exceeds the logical window."""
    q, ks, vs, pool, tables, positions, fmt = _chunk_setup(
        "kv_fp16", C=3, start=18)   # cache_len=16 → the ring has wrapped:
                                    # page 0 holds tags {16, 17, 2, 3}
    for window in (0, 8):
        ref = _chunk_reference(q, ks, vs, pool, tables, positions,
                               window=window, fmt=fmt)
        out = fused_chunk_attention(q, ks, vs, pool, tables, positions,
                                    window=window, fmt=fmt,
                                    out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)


def test_fused_chunk_null_block_padding():
    """-1 table tails resolve to the null block; padded query rows
    (positions = -1, the verify step's short-draft rows) produce garbage
    both paths discard — parity is asserted on live rows only."""
    q, ks, vs, pool, tables, positions, fmt = _chunk_setup(
        "kv8_channel", C=3, start=5)
    tables = tables.at[1, 2:].set(-1)
    positions = positions.at[1, 1:].set(-1)     # slot 1: one live query
    ref = _chunk_reference(q, ks, vs, pool, tables, positions,
                           window=0, fmt=fmt)
    out = fused_chunk_attention(q, ks, vs, pool, tables, positions,
                                window=0, fmt=fmt, out_dtype=jnp.float32)
    live = np.asarray(positions) >= 0
    np.testing.assert_allclose(np.asarray(out)[live], np.asarray(ref)[live],
                               rtol=2e-5, atol=2e-6)


def test_fused_chunk_masks_pool_entries_at_chunk_positions():
    """Single-counting: pool entries tagged >= positions[:, 0] (a sharing
    peer's copy of the same tokens, or stale rejected drafts) must not be
    double-attended alongside the in-flight segment."""
    q, ks, vs, pool, tables, positions, fmt = _chunk_setup(
        "kv_fp16", C=3, start=6)
    # poison the pool at the chunk's own positions with junk copies
    cache_len = 16
    for j in range(3):
        junk = jnp.full((2, 2, 32), 37.0, jnp.float32)
        pool = kvc.paged_insert(pool, tables, junk, junk,
                                jnp.full((2,), 6 + j, jnp.int32),
                                cache_len=cache_len, fmt=fmt)
    ref = _chunk_reference(q, ks, vs, pool, tables, positions,
                          window=0, fmt=fmt)
    out = fused_chunk_attention(q, ks, vs, pool, tables, positions,
                                window=0, fmt=fmt, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_fused_chunk_interpret_toggle():
    q, ks, vs, pool, tables, positions, fmt = _chunk_setup("kv_fp16")
    auto = fused_chunk_attention(q, ks, vs, pool, tables, positions,
                                 window=0, fmt=fmt, out_dtype=jnp.float32)
    forced = fused_chunk_attention(q, ks, vs, pool, tables, positions,
                                   window=0, fmt=fmt,
                                   out_dtype=jnp.float32, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(forced))


# ---------------------------------------------------------------------------
# gather_window fp16 fast path (satellite)
# ---------------------------------------------------------------------------

def test_gather_window_fp16_skips_dequant(monkeypatch):
    """Passthrough pools must not route through kv_dequantize (no dequant
    pass, no scale gathers) — the pre-fix behavior cost an extra pool-sized
    elementwise pass per decode step."""
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    want = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32)

    def boom(*a, **k):
        raise AssertionError("kv_dequantize called for a passthrough format")

    monkeypatch.setattr(kvc, "kv_dequantize", boom)
    got = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got.k), np.asarray(want.k))
    np.testing.assert_array_equal(np.asarray(got.pos), np.asarray(want.pos))
    # quantized pools still dequantize
    q2, qpool, t2, p2, qfmt = _filled_pool("kv8_channel")
    with pytest.raises(AssertionError, match="passthrough"):
        kvc.gather_window(qpool, t2, fmt=qfmt, out_dtype=jnp.float32)


def test_gather_window_fp16_dtype_cast():
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    win = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.bfloat16)
    assert win.k.dtype == jnp.bfloat16 and win.v.dtype == jnp.bfloat16


def test_paged_decode_attention_rejects_unknown_path():
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16")
    with pytest.raises(ValueError, match="unknown attn_path"):
        kvc.paged_decode_attention(q, pool, tables, pos, fmt=fmt,
                                   out_dtype=jnp.float32, attn_path="ring")


# ---------------------------------------------------------------------------
# planner: ring vs gather vs fused as a costed decision
# ---------------------------------------------------------------------------

def _problem(**kw):
    base = dict(B=4, Hq=32, Hkv=8, D=128, cache_len=4096, page_size=16,
                kv_format="kv8_channel", paged=True, backend="tpu")
    base.update(kw)
    return planning.AttentionProblem(**base)


def test_plan_attention_backend_split():
    """The acceptance decision: fused wins on TPU for long-context paged
    decode (one trip over the pool); the interpret penalty keeps the XLA
    gather in front on CPU hosts."""
    assert planning.plan_attention(_problem()).path == "fused"
    assert planning.plan_attention(_problem(kv_format="kv_fp16")).path \
        == "fused"
    assert planning.plan_attention(_problem(backend="cpu")).path == "gather"
    # non-paged engines only have the ring layout
    assert planning.plan_attention(
        _problem(paged=False, kv_format="kv_fp16")).path == "ring"


def test_plan_attention_costs_charge_gather_roundtrip():
    """The roofline entries price the gather's HBM round-trip: on TPU the
    gather path is strictly more bytes (and time) than fused for the same
    problem, and the gap grows with context."""
    from repro.core import costmodel as cm
    for ctx in (1024, 4096, 16384):
        gb = cm.paged_attn_bytes("gather", 4, 32, 8, 128, ctx,
                                 quantized=True)
        fb = cm.paged_attn_bytes("fused", 4, 32, 8, 128, ctx,
                                 quantized=True, kv_partitions=8)
        assert fb < gb
        assert cm.attn_decode_time_tpu("fused", 4, 32, 8, 128, ctx,
                                       quantized=True, kv_partitions=8) < \
            cm.attn_decode_time_tpu("gather", 4, 32, 8, 128, ctx,
                                    quantized=True)


def test_plan_attention_forced_path_validation():
    with pytest.raises(ValueError, match="unknown attention path"):
        planning.plan_attention(_problem(), path="flash3")
    with pytest.raises(ValueError, match="does not support"):
        planning.plan_attention(_problem(), path="ring")      # paged
    with pytest.raises(ValueError, match="does not support"):
        planning.plan_attention(_problem(paged=False), path="fused")
    plan = planning.plan_attention(_problem(backend="cpu"), path="fused")
    assert plan.path == "fused"            # forcing beats the cost ranking


def test_choose_kv_partitions_occupancy():
    cores = planning.num_cores()
    # grid already full → no split
    assert planning.choose_kv_partitions(cores, 1, 64) == 1
    # underfilled grid → split up to the core count, power-of-2 divisor
    s = planning.choose_kv_partitions(1, 1, 64)
    assert s >= 1 and 64 % s == 0 and (s & (s - 1)) == 0
    if cores >= 2:
        assert s > 1
    # never more partitions than pages
    assert planning.choose_kv_partitions(1, 1, 1) == 1


def test_choose_kv_partitions_q_tiles_occupancy():
    """Multi-query tiles count toward grid occupancy: a chunk that already
    fills the cores leaves no reason to Split-K."""
    cores = planning.num_cores()
    assert planning.choose_kv_partitions(1, 1, 64, q_tiles=cores) == 1
    assert planning.choose_kv_partitions(1, 1, 64, q_tiles=1) >= \
        planning.choose_kv_partitions(1, 1, 64, q_tiles=cores)


def test_choose_q_block():
    """Q-tile sizing: the largest divisor of q_len whose row block
    (tile × group) stays within one 128-lane register tile."""
    assert planning.choose_q_block(1, 8) == 1
    assert planning.choose_q_block(32, 4) == 32        # 32·4 = 128 exactly
    assert planning.choose_q_block(32, 8) == 16        # cap 128//8
    assert planning.choose_q_block(5, 6) == 5          # k+1 verify widths fit
    t = planning.choose_q_block(12, 16)
    assert t == 6 and 12 % t == 0
    assert planning.choose_q_block(7, 64) == 1         # prime over a tiny cap


def test_plan_attention_multi_query_costed():
    """The q_len-aware decision: fused wins on TPU for chunked prefill
    (q_len=chunk) and speculative verify (q_len=k+1) because gather still
    materializes the full window per call; CPU hosts keep gather. The
    byte model itself must rank fused strictly cheaper."""
    from repro.core import costmodel as cm
    for ql in (5, 32):
        assert planning.plan_attention(
            _problem(B=1, q_len=ql)).path == "fused"
        assert planning.plan_attention(
            _problem(B=1, q_len=ql, backend="cpu")).path == "gather"
        gb = cm.paged_attn_bytes("gather", 1, 32, 8, 128, 4096,
                                 quantized=True, q_len=ql)
        fb = cm.paged_attn_bytes("fused", 1, 32, 8, 128, 4096,
                                 quantized=True, kv_partitions=8, q_len=ql)
        assert fb < gb
        assert cm.attn_decode_time_tpu(
            "fused", 1, 32, 8, 128, 4096, quantized=True,
            kv_partitions=8, q_len=ql) < cm.attn_decode_time_tpu(
            "gather", 1, 32, 8, 128, 4096, quantized=True, q_len=ql)


# ---------------------------------------------------------------------------
# gather_window live-page clamp (satellite)
# ---------------------------------------------------------------------------

def test_gather_window_live_pages_clamp():
    """Clamping at (or above) the per-slot high-water mark drops only
    never-written pages: the surviving window is identical and the
    attention output unchanged — the over-gather fix for young slots."""
    q, pool, tables, pos, fmt = _filled_pool("kv_fp16", fill=6)  # 2 pages hot
    full = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32)
    assert np.all(np.asarray(full.pos[:, 8:]) == -1)   # tail is empty anyway
    clamped = kvc.gather_window(pool, tables, fmt=fmt,
                                out_dtype=jnp.float32, live_pages=2)
    assert clamped.k.shape[1] == 2 * 4                 # 2 pages × page_size 4
    np.testing.assert_array_equal(np.asarray(clamped.k),
                                  np.asarray(full.k[:, :8]))
    np.testing.assert_array_equal(np.asarray(clamped.pos),
                                  np.asarray(full.pos[:, :8]))
    ref = kvc.paged_decode_attention(q, pool, tables, pos, fmt=fmt,
                                     out_dtype=jnp.float32)
    out = kvc.paged_decode_attention(q, pool, tables, pos, fmt=fmt,
                                     out_dtype=jnp.float32, live_pages=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
    # a clamp wider than the table is a no-op, and the floor is one page
    wide = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32,
                             live_pages=99)
    assert wide.k.shape == full.k.shape
    assert kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32,
                             live_pages=0).k.shape[1] == 4


def test_engine_live_bucket():
    """_live_bucket covers the high-water mark with a power-of-2 fraction
    of the slot table (bounded recompiles), returning None (= full table)
    once the mark is past half the ring."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    eng = ServingEngine(cfg, _params(cfg), max_batch=2, max_prompt_len=8,
                        max_new_tokens=4, page_size=4)
    w = eng.pages_slot
    assert eng._live_bucket(w) is None
    assert eng._live_bucket(w + 5) is None             # clamped, not wider
    for hw in range(1, w + 1):
        b = eng._live_bucket(hw)
        if b is None:
            assert 2 * hw > w or w % 2 == 1
        else:
            assert hw <= b < w and w % b == 0


# ---------------------------------------------------------------------------
# engine-level token parity: fused ≡ gather across archs × formats
# ---------------------------------------------------------------------------

def _params(cfg, quantized=True):
    p = T.init_params(KEY, cfg)
    return T.quantize_params(p, cfg, min_size=0) if quantized else p


def _requests(cfg, n, P, G, *, same_prompt=False):
    toks = jax.random.randint(KEY, (n, P), 0, cfg.vocab_size)
    reqs = []
    for i in range(n):
        kw = {}
        if cfg.vision_prefix:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, 0 if same_prompt else i),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        reqs.append(Request(rid=i, prompt=toks[0] if same_prompt else toks[i],
                            max_new_tokens=G, **kw))
    return reqs


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "internvl2-1b"])
@pytest.mark.parametrize("kv_format", ["kv_fp16", "kv8_channel"])
def test_fused_engine_parity(arch, kv_format):
    """Fused-paged decode is token-identical to gather decode on the SWA
    (ring-wrap) and vision-prefix archs, both KV formats — the tentpole
    acceptance. Prompts run past the danube window so pages wrap."""
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="xla")
    P, G, n = 12, 6, 2
    params = _params(cfg)

    def run(path):
        eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                            max_new_tokens=G, page_size=4,
                            kv_format=kv_format, attn_path=path)
        assert eng.attn_path == path
        return eng.run(_requests(cfg, n, P, G)).results

    got, want = run("fused"), run("gather")
    assert got == want and sorted(got) == list(range(n))


def test_fused_engine_parity_shared_prefix_cow():
    """Shared-prefix CoW arch case: identical prompts alias prompt pages
    until the divergent decode write copies them — the fused walk reads
    the exact same physical pages the gather path does."""
    cfg = dataclasses.replace(configs.get_reduced("internvl2-1b"),
                              w4a16_strategy="xla")
    P, G, n = 8, 4, 2
    params = _params(cfg)

    def run(path):
        eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                            max_new_tokens=G, page_size=4, attn_path=path)
        rep = eng.run(_requests(cfg, n, P, G, same_prompt=True))
        return rep.results, rep.peak_pages

    got, pages_f = run("fused")
    want, pages_g = run("gather")
    assert got == want
    assert got[0] == got[1]                 # same prompt → same greedy run
    assert pages_f == pages_g               # identical allocator behavior


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "internvl2-1b"])
def test_fused_chunked_prefill_parity(arch):
    """Multi-chunk prefill (prompt split 5 tokens at a time) through the
    fused multi-query kernel is token-identical to the gather path — SWA
    ring-wrap and vision-prefix archs, quantized pool."""
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="xla")
    P, G, n = 12, 4, 2
    params = _params(cfg)

    def run(path):
        eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                            max_new_tokens=G, page_size=4, prefill_chunk=5,
                            kv_format="kv8_channel", attn_path=path)
        assert eng.prefill_attn_path == path
        return eng.run(_requests(cfg, n, P, G)).results

    got, want = run("fused"), run("gather")
    assert got == want and sorted(got) == list(range(n))


def test_fused_verify_parity_ngram():
    """Speculative verify (q_len = k+1) through the fused kernel: same
    tokens AND same acceptance counts as the gather path on repetitive
    prompts the ngram proposer actually drafts against."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    G, n = 8, 2
    params = _params(cfg)
    prompt = jnp.asarray([5, 6, 7, 5, 6, 7, 5, 6, 7, 5], jnp.int32)

    def run(path):
        eng = ServingEngine(cfg, params, max_batch=n,
                            max_prompt_len=len(prompt), max_new_tokens=G,
                            page_size=4, speculate="ngram", spec_k=3,
                            attn_path=path)
        assert eng.verify_attn_path == path
        rep = eng.run([Request(rid=i, prompt=prompt, max_new_tokens=G)
                       for i in range(n)])
        return rep.results, rep.proposed_tokens, rep.accepted_tokens

    (got, prop_f, acc_f), (want, prop_g, acc_g) = run("fused"), run("gather")
    assert got == want and sorted(got) == list(range(n))
    assert (prop_f, acc_f) == (prop_g, acc_g)


def test_engine_multi_query_path_metrics():
    """Per-regime plan resolution is exported: chunked engines surface the
    prefill path gauge, speculative engines the verify path gauge."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                        max_new_tokens=3, page_size=4, prefill_chunk=4,
                        speculate="ngram", spec_k=2)
    want = "fused" if jax.default_backend() == "tpu" else "gather"
    assert eng.prefill_attn_path == want
    assert eng.verify_attn_path == want
    eng.metrics = rmetrics.MetricsRegistry()
    eng.run(_requests(cfg, 2, 8, 3))
    text = eng.metrics.render()
    assert "engine_prefill_attn_path" in text
    assert "engine_verify_attn_path" in text


def test_engine_attn_path_resolution_and_metrics():
    """auto resolves per backend (gather on CPU CI), the resolved path is
    exported as a /metrics gauge + per-path step counter, and fused on a
    non-paged engine is refused loudly."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                        max_new_tokens=3, page_size=4)
    assert eng.attn_path == ("fused" if jax.default_backend() == "tpu"
                             else "gather")
    eng.metrics = rmetrics.MetricsRegistry()
    eng.run(_requests(cfg, 2, 8, 3))
    text = eng.metrics.render()
    assert f"engine_attn_path {float(1 if eng.attn_path == 'gather' else 2)}" \
        in text.replace(".0", "") or "engine_attn_path" in text
    assert f"engine_attn_path_steps_{eng.attn_path}" in text
    with pytest.raises(ValueError, match="does not support"):
        ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                      max_new_tokens=3, paged=False, attn_path="fused")
    ring = ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                         max_new_tokens=3, paged=False)
    assert ring.attn_path == "ring"


# ---------------------------------------------------------------------------
# multi-device parity (subprocess with 8 fake CPU devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax

from repro import configs
from repro.kernels import planning
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.runtime.engine import Request, ServingEngine

out = {}
P, G, R, SLOTS = 8, 4, 2, 2
arch = "h2o-danube-1.8b"
cfg = configs.get_reduced(arch)
key = jax.random.PRNGKey(0)
params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
toks = jax.random.randint(key, (R, P), 0, cfg.vocab_size)


def run_engine(mesh, attn_path, **kw):
    planning.PLAN_CACHE.clear()
    eng = ServingEngine(cfg, params, mesh=mesh, max_batch=SLOTS,
                        max_prompt_len=P, max_new_tokens=G, page_size=4,
                        attn_path=attn_path, **kw)
    reqs = [Request(rid=i, prompt=toks[i], max_new_tokens=G)
            for i in range(R)]
    return {str(k): v for k, v in sorted(eng.run(reqs).results.items())}


single_gather = run_engine(None, "gather")
single_fused = run_engine(None, "fused")
out["single/fused==gather"] = single_fused == single_gather
mesh = make_local_mesh(data=2, model=4)
# multi-query regimes on the mesh: 5-token prefill chunks + ngram verify
# (q_len=k+1) all forced through the fused kernel — greedy speculative
# decode is lossless, so tokens must still match plain single-device gather
sharded_fused = run_engine(mesh, "fused", prefill_chunk=5,
                           speculate="ngram", spec_k=2)
out["tp4xdp2/mq fused==single"] = sharded_fused == single_gather
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_fused_engine_parity():
    """Forced-fused decode on a TP=4 x DP=2 mesh (8 fake CPU devices) is
    token-identical to single-device gather decode."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out and all(out.values()), {k: v for k, v in out.items() if not v}
