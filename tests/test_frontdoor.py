"""Front-door + stepper tests: re-entrant engine API equivalence with
``run()``, mid-decode cancellation (allocator-exact page release), the
bounded admission queue's 429/408 semantics, SSE streaming over real
sockets token-identical to ``engine.run()`` (danube + internvl2, with and
without the ngram proposer), and the metrics plane agreeing with the
final ``ServeReport``."""
import asyncio
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T
from repro.runtime import metrics as rmetrics
from repro.runtime.engine import Request, ServingEngine
from repro.runtime.frontdoor import (FrontDoor, QueueSettings,
                                     sse_decode_tokens)

KEY = jax.random.PRNGKey(0)
P, G, B = 8, 6, 2

_PARAMS = {}


def _setup(arch):
    if arch not in _PARAMS:
        cfg = dataclasses.replace(configs.get_reduced(arch),
                                  w4a16_strategy="xla")
        _PARAMS[arch] = (cfg, T.quantize_params(T.init_params(KEY, cfg),
                                                cfg, min_size=0))
    return _PARAMS[arch]


def _engine(arch, **kw):
    cfg, params = _setup(arch)
    kw.setdefault("page_size", 4)
    kw.setdefault("prefill_chunk", 4)
    return ServingEngine(cfg, params, max_batch=B, max_prompt_len=P,
                         max_new_tokens=G, **kw)


def _prompts(cfg, n, *, length=P):
    toks = jax.random.randint(KEY, (n, length), 0, cfg.vocab_size)
    return [[int(t) for t in toks[i]] for i in range(n)]


def _embeds(cfg, i):
    return jax.random.normal(jax.random.fold_in(KEY, i),
                             (cfg.vision_prefix, cfg.d_model), cfg.dtype)


def _requests(cfg, prompts, **kw):
    reqs = []
    for i, p in enumerate(prompts):
        extra = dict(kw)
        if cfg.vision_prefix:
            extra["prefix_embeds"] = _embeds(cfg, i)
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=G, **extra))
    return reqs


# ---------------------------------------------------------------------------
# metrics plane: nearest-rank percentiles + registry
# ---------------------------------------------------------------------------

def test_nearest_rank_and_summarize():
    vs = [5.0, 1.0, 4.0, 2.0, 3.0]
    assert rmetrics.nearest_rank(vs, 0.5) == 3.0
    assert rmetrics.nearest_rank(vs, 0.95) == 5.0
    assert rmetrics.nearest_rank(vs, 1.0) == 5.0
    assert rmetrics.nearest_rank([7.0], 0.01) == 7.0   # ceil clamps to 1
    assert rmetrics.nearest_rank([], 0.99) == 0.0
    with pytest.raises(ValueError):
        rmetrics.nearest_rank(vs, 0.0)
    with pytest.raises(ValueError):
        rmetrics.nearest_rank(vs, 1.5)
    s = rmetrics.summarize(vs)
    assert (s["p50"], s["p95"], s["p99"]) == (3.0, 5.0, 5.0)
    assert s["max"] == 5.0 and s["count"] == 5 and s["mean"] == 3.0
    empty = rmetrics.summarize([])
    assert empty["p99"] == 0.0 and empty["count"] == 0


def test_registry_render_and_types():
    reg = rmetrics.MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(2)
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g_depth")
    g.set(4)
    g.set(1)
    assert g.value == 1 and g.peak == 4
    h = reg.histogram("h_seconds")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    assert h.percentile(0.5) == 0.2 and h.count == 3
    # get-or-create returns the same object; kind conflicts are refused
    assert reg.counter("c_total") is c
    with pytest.raises(ValueError):
        reg.gauge("c_total")
    text = reg.render()
    assert "# TYPE c_total counter" in text and "c_total 3" in text
    assert "# HELP c_total a counter" in text
    assert 'h_seconds{quantile="0.5"} 0.2' in text
    assert "h_seconds_count 3" in text
    snap = reg.snapshot()
    assert snap["c_total"] == 3
    assert snap["g_depth"] == {"value": 1.0, "peak": 4.0}
    assert snap["h_seconds"]["p50"] == 0.2


def test_sse_decode_tokens():
    payload = (b"HTTP/1.1 200 OK\r\n\r\n"
               b"data: {\"rid\": 0, \"tokens\": [1, 2]}\r\n\r\n"
               b"data: {\"rid\": 0, \"tokens\": [3]}\r\n\r\n"
               b"event: done\r\ndata: {\"rid\": 0, \"n\": 3}\r\n\r\n")
    assert sse_decode_tokens(payload) == [1, 2, 3]


# ---------------------------------------------------------------------------
# stepper API: equivalence with run(), admission ordering, cancellation
# ---------------------------------------------------------------------------

def _drive_stepper(eng, reqs):
    """Drive submit/step by hand, collecting per-rid streamed tokens and
    the admission order."""
    eng.start()
    for r in reqs:
        eng.submit(r)
    streamed, order = {}, []
    while eng.has_work():
        ev = eng.step()
        order.extend(ev.admitted)
        for rid, toks in ev.emitted.items():
            streamed.setdefault(rid, []).extend(toks)
    return streamed, order


def test_stepper_matches_run():
    cfg, _ = _setup("h2o-danube-1.8b")
    prompts = _prompts(cfg, 3)
    eng = _engine("h2o-danube-1.8b")
    ref = eng.run(_requests(cfg, prompts))
    streamed, _ = _drive_stepper(eng, _requests(cfg, prompts))
    assert streamed == ref.results
    rep = eng.report
    assert rep.results == ref.results and rep.admitted == 3
    assert sorted(rep.ttft) == [0, 1, 2]
    assert all(t >= 0 for t in rep.ttft.values())
    # the streaming contract: a no-work step reports worked=False
    assert eng.step().worked is False


def test_run_ignores_deadline_and_priority():
    """Satellite: deadline_s/priority only shape *admission order* under
    admission='priority'; plain FIFO run() is byte-identical without."""
    cfg, _ = _setup("h2o-danube-1.8b")
    prompts = _prompts(cfg, 3)
    plain = _engine("h2o-danube-1.8b").run(_requests(cfg, prompts))
    tagged = _engine("h2o-danube-1.8b").run(
        _requests(cfg, prompts, deadline_s=0.001, priority=7))
    assert tagged.results == plain.results
    assert tagged.steps == plain.steps


def test_priority_admission_order():
    cfg, _ = _setup("h2o-danube-1.8b")
    prompts = _prompts(cfg, 3)
    eng = ServingEngine(cfg, _setup("h2o-danube-1.8b")[1], max_batch=1,
                        max_prompt_len=P, max_new_tokens=G, page_size=4,
                        prefill_chunk=4, admission="priority")
    reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=G, priority=0),
            Request(rid=1, prompt=prompts[1], max_new_tokens=G, priority=5),
            Request(rid=2, prompt=prompts[2], max_new_tokens=G, priority=5,
                    deadline_s=0.5)]
    _, order = _drive_stepper(eng, reqs)
    # highest priority first; deadline breaks the tie within priority 5
    assert order == [2, 1, 0]
    with pytest.raises(ValueError, match="admission"):
        ServingEngine(cfg, _setup("h2o-danube-1.8b")[1], max_batch=1,
                      max_prompt_len=P, max_new_tokens=G,
                      admission="wrong")


def test_cancel_mid_decode_with_shared_prefix():
    """Cancelling one of two requests sharing prefix pages mid-decode
    evicts its slot and decrefs its pages; the survivor's generation is
    token-identical to a solo run and the allocator ends exactly empty."""
    cfg, _ = _setup("h2o-danube-1.8b")
    prompt = _prompts(cfg, 1)[0]
    eng = _engine("h2o-danube-1.8b")
    ref = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=G)])
    eng.start()
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=G))
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=G))
    streamed = {}
    cancelled = False
    while eng.has_work():
        ev = eng.step()
        for rid, toks in ev.emitted.items():
            streamed.setdefault(rid, []).extend(toks)
        if not cancelled and streamed.get(0) and streamed.get(1):
            pages_before = eng.alloc.pages_in_use
            assert eng.cancel(0) is True
            assert eng.alloc.pages_in_use < pages_before
            cancelled = True
    assert cancelled, "both requests finished before a cancel point"
    rep = eng.report
    assert rep.cancelled[0] == streamed[0] and 0 not in rep.results
    assert rep.results[1] == ref.results[0]
    assert eng.alloc.pages_in_use == 0
    assert eng.cancel(0) is False                  # unknown rid: no-op


def test_cancel_mid_chunked_prefill():
    cfg, _ = _setup("h2o-danube-1.8b")
    prompt = _prompts(cfg, 1)[0]
    eng = _engine("h2o-danube-1.8b", prefill_chunk=2)   # P=8: 4 chunks
    eng.start()
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=G))
    ev = eng.step()                                # one 2-token chunk in
    assert ev.emitted.get(0) in (None, [])         # still prefilling
    assert eng.alloc.pages_in_use > 0
    assert eng.cancel(0) is True
    assert eng.alloc.pages_in_use == 0
    assert not eng.has_work()
    assert eng.report.cancelled[0] == []


def test_cancel_waiting_request_never_touches_allocator():
    cfg, _ = _setup("h2o-danube-1.8b")
    prompts = _prompts(cfg, 2)
    eng = _engine("h2o-danube-1.8b")
    eng.start()
    for i in range(2):
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=G))
    assert eng.cancel(1) is True                   # still in the queue
    assert eng.report.cancelled[1] == []
    rep = eng.drain()
    assert sorted(rep.results) == [0]
    assert eng.alloc.pages_in_use == 0


def test_submit_before_start_raises():
    cfg, _ = _setup("h2o-danube-1.8b")
    eng = _engine("h2o-danube-1.8b")
    with pytest.raises(RuntimeError, match="start"):
        eng.submit(Request(rid=0, prompt=_prompts(cfg, 1)[0],
                           max_new_tokens=G))


# ---------------------------------------------------------------------------
# HTTP front door over real sockets
# ---------------------------------------------------------------------------

async def _raw(port, head, body=b""):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(head + body)
    await writer.drain()
    payload = await reader.read()
    writer.close()
    return payload


async def _post(port, spec):
    body = json.dumps(spec).encode()
    head = (f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
            f"Content-Length: {len(body)}\r\n\r\n").encode()
    payload = await _raw(port, head, body)
    return int(payload.split(b" ", 2)[1]), payload


async def _get(port, path):
    payload = await _raw(
        port, f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    return int(payload.split(b" ", 2)[1]), payload


def _run_async(coro, timeout=600):
    return asyncio.run(asyncio.wait_for(coro, timeout))


@pytest.mark.parametrize("arch,speculate", [
    ("h2o-danube-1.8b", None),
    ("h2o-danube-1.8b", "ngram"),
    ("internvl2-1b", None),
    ("internvl2-1b", "ngram"),
])
def test_http_streams_match_run(arch, speculate):
    """Acceptance: concurrent real-socket SSE streams are token-identical
    to engine.run() — danube + internvl2 (prefix embeds over the wire),
    paged, with and without the ngram proposer."""
    from repro.runtime import speculative
    cfg, _ = _setup(arch)
    kw = {}
    if speculate:
        # repetitive prompts (one 4-token segment tiled to P) so the
        # prompt-lookup proposer actually proposes something to verify
        seg = jax.random.randint(KEY, (3, P // 2), 0, cfg.vocab_size)
        prompts = [[int(t) for t in jnp.tile(seg[i], 2)] for i in range(3)]
        kw.update(speculate=speculative.make_proposer("ngram",
                                                      target_cfg=cfg),
                  spec_k=2)                       # window=16 on danube
    else:
        prompts = _prompts(cfg, 3)
    eng = _engine(arch, **kw)
    ref = eng.run(_requests(cfg, prompts))

    def spec(i):
        s = {"prompt": prompts[i], "max_new_tokens": G}
        if cfg.vision_prefix:
            s["prefix_embeds"] = [[float(x) for x in row]
                                  for row in _embeds(cfg, i)]
        return s

    async def main():
        fd = FrontDoor(eng, settings=QueueSettings(queue_depth=8))
        await fd.serve()
        outs = await asyncio.gather(*(_post(fd.port, spec(i))
                                      for i in range(3)))
        report = await fd.shutdown()
        return outs, report

    outs, report = _run_async(main())
    assert all(status == 200 for status, _ in outs)
    got = [sse_decode_tokens(payload) for _, payload in outs]
    assert got == [ref.results[i] for i in range(3)]
    assert eng.alloc.pages_in_use == 0
    assert report.admitted == 3 and not report.cancelled
    if speculate:
        assert report.proposed_tokens > 0


def test_http_cancel_mid_stream():
    """Acceptance: a client disconnecting mid-stream evicts its slot and
    frees its pages while concurrent streams finish token-identical."""
    cfg, _ = _setup("h2o-danube-1.8b")
    prompts = _prompts(cfg, 3)
    eng = _engine("h2o-danube-1.8b")
    ref = eng.run(_requests(cfg, prompts))

    async def canceller(port):
        body = json.dumps({"prompt": prompts[0],
                           "max_new_tokens": G}).encode()
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        await reader.readuntil(b"\r\n\r\n")        # response headers
        await reader.readuntil(b"\r\n\r\n")        # first token event
        writer.close()                             # hang up mid-stream
        await writer.wait_closed()

    async def main():
        fd = FrontDoor(eng, settings=QueueSettings(queue_depth=8))
        await fd.serve()
        first, *rest = await asyncio.gather(
            canceller(fd.port),
            *(_post(fd.port, {"prompt": prompts[i], "max_new_tokens": G})
              for i in (1, 2)))
        report = await fd.shutdown()
        return rest, report

    rest, report = _run_async(main())
    assert [sse_decode_tokens(p) for _, p in rest] == [ref.results[1],
                                                       ref.results[2]]
    # rid 0 was the first connection's; it must be gone from results and
    # recorded as cancelled with however many tokens it got out
    (crid,) = report.cancelled
    assert crid not in report.results
    assert len(report.cancelled[crid]) < G
    assert eng.alloc.pages_in_use == 0
    assert eng.metrics.get("frontdoor_cancelled_total").value == 1


def test_http_429_and_408_without_touching_engine():
    """Acceptance: queue-full 429 and expired-deadline 408 happen entirely
    at the front door — the engine never runs a step for them."""
    cfg, _ = _setup("h2o-danube-1.8b")
    prompts = _prompts(cfg, 3)
    eng = _engine("h2o-danube-1.8b")

    async def main():
        fd = FrontDoor(eng, settings=QueueSettings(queue_depth=1))
        await fd.serve(start_driver=False)         # queue can only fill
        # immediate 408: deadline already spent on arrival
        s408, p408 = await _post(fd.port, {
            "prompt": prompts[0], "max_new_tokens": G, "deadline_s": 0})
        # expired-in-queue 408: enqueued, deadline passes pre-admission
        slow = asyncio.create_task(_post(fd.port, {
            "prompt": prompts[1], "max_new_tokens": G,
            "deadline_s": 0.05}))
        await asyncio.sleep(0.02)                  # let it enqueue
        # queue is now full (depth 1): next request is shed as 429
        s429, _ = await _post(fd.port, {"prompt": prompts[2],
                                        "max_new_tokens": G})
        await asyncio.sleep(0.1)                   # deadline passes
        assert eng.report.steps == 0               # engine untouched
        assert eng.alloc.pages_in_use == 0
        fd.start_driver()
        s_slow, _ = await slow
        report = await fd.shutdown()
        return s408, p408, s429, s_slow, report

    s408, p408, s429, s_slow, report = _run_async(main())
    assert s408 == 408 and b"deadline" in p408
    assert s429 == 429
    assert s_slow == 408                           # expired while queued
    assert report.rejected_429 == 1 and report.rejected_408 == 2
    assert report.steps == 0 and not report.results


def test_http_metrics_agree_with_report():
    """Acceptance: GET /metrics and the final ServeReport agree on
    admitted/rejected counts, queue depth peak and latency quantiles."""
    cfg, _ = _setup("h2o-danube-1.8b")
    prompts = _prompts(cfg, 3)
    eng = _engine("h2o-danube-1.8b")

    async def main():
        fd = FrontDoor(eng, settings=QueueSettings(queue_depth=8))
        await fd.serve()
        await asyncio.gather(*(
            _post(fd.port, {"prompt": prompts[i], "max_new_tokens": G})
            for i in range(3)))
        status, payload = await _get(fd.port, "/metrics")
        sh, ph = await _get(fd.port, "/healthz")
        report = await fd.shutdown()
        return status, payload, sh, ph, report, fd.metrics

    status, payload, sh, ph, report, m = _run_async(main())
    assert status == 200 and sh == 200
    assert json.loads(ph.split(b"\r\n\r\n", 1)[1])["ok"] is True
    text = payload.split(b"\r\n\r\n", 1)[1].decode()
    assert "# TYPE engine_queue_depth gauge" in text
    assert f"engine_admitted_total {report.admitted}" in text
    assert report.admitted == 3
    assert m.get("frontdoor_rejected_429_total").value == report.rejected_429
    assert m.get("frontdoor_rejected_408_total").value == report.rejected_408
    assert m.get("frontdoor_queue_depth").peak == report.peak_queue_depth
    assert m.get("engine_e2e_seconds").summary() == report.latency_stats()
    assert m.get("engine_ttft_seconds").summary() == report.ttft_stats()
    assert m.get("engine_pages_in_use").value == 0


def test_http_rejects_malformed_requests():
    cfg, _ = _setup("h2o-danube-1.8b")
    eng = _engine("h2o-danube-1.8b")
    good = _prompts(cfg, 1)[0]

    async def main():
        fd = FrontDoor(eng)
        await fd.serve()
        out = {
            "no_prompt": (await _post(fd.port, {}))[0],
            "empty": (await _post(fd.port, {"prompt": []}))[0],
            "non_int": (await _post(fd.port, {"prompt": ["a"]}))[0],
            "too_long": (await _post(
                fd.port, {"prompt": list(range(P + 1))}))[0],
            "bad_gen": (await _post(
                fd.port, {"prompt": good, "max_new_tokens": 0}))[0],
            "embeds": (await _post(
                fd.port, {"prompt": good,
                          "prefix_embeds": [[0.0]]}))[0],
            "lost": (await _get(fd.port, "/nope"))[0],
        }
        report = await fd.shutdown()
        return out, report

    out, report = _run_async(main())
    assert out == {"no_prompt": 400, "empty": 400, "non_int": 400,
                   "too_long": 400, "bad_gen": 400, "embeds": 400,
                   "lost": 404}
    assert report.steps == 0 and report.admitted == 0
