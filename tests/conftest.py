import os
import sys

# tests see the default single CPU device (the dry-run, and only the dry-run,
# forces 512 — see src/repro/launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
