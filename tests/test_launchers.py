"""CLI driver smokes: train + serve on reduced configs (the example paths),
plus data_shardings edge cases the drivers feed it (0-d leaves)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main
from repro.runtime import sharding as shd


def test_train_cli_reduced(tmp_path):
    losses = train_main([
        "--arch", "internvl2-1b", "--reduced", "--steps", "4",
        "--batch", "2", "--seq", "16",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    ])
    assert len(losses) == 4 and all(jnp.isfinite(l) for l in losses)


def test_serve_cli_quantized_fused(tmp_path):
    gen = serve_main([
        "--arch", "olmoe-1b-7b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "4", "--strategy", "xla",
    ])
    assert gen.shape == (2, 4)
    assert int(gen.min()) >= 0


def test_serve_cli_encdec(tmp_path):
    gen = serve_main([
        "--arch", "whisper-small", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "3", "--strategy", "xla",
    ])
    assert gen.shape == (2, 3)


def test_serve_cli_arrival_simulation(tmp_path):
    """More requests than slots, staggered arrivals — the continuous-
    batching path of the engine behind the CLI."""
    gen = serve_main([
        "--arch", "olmoe-1b-7b", "--reduced", "--batch", "2",
        "--requests", "3", "--arrival-every", "1",
        "--prompt-len", "8", "--gen", "3", "--strategy", "xla",
        "--plan-cache", str(tmp_path / "plans.json"),
    ])
    assert gen.shape == (3, 3)


def test_serve_cli_variable_prompt_len():
    """--prompt-len MIN:MAX draws a length per request; the fixed-N form
    stays the default path."""
    from repro.launch.serve import parse_prompt_len
    assert parse_prompt_len("32") == (32, 32)
    assert parse_prompt_len("4:8") == (4, 8)
    with pytest.raises(ValueError, match="MIN:MAX"):
        parse_prompt_len("4:x")
    with pytest.raises(ValueError, match="MIN <= MAX"):
        parse_prompt_len("8:4")
    gen = serve_main([
        "--arch", "olmoe-1b-7b", "--reduced", "--batch", "2",
        "--requests", "3", "--prompt-len", "4:8", "--gen", "3",
        "--strategy", "xla",
    ])
    assert gen.shape == (3, 3)


def test_serve_cli_http_front_door():
    """--http 0 routes the same arrival simulation through real-socket
    SSE clients against the asyncio front door."""
    gen = serve_main([
        "--arch", "h2o-danube-1.8b", "--reduced", "--batch", "2",
        "--requests", "3", "--arrival-every", "1",
        "--prompt-len", "8", "--gen", "3", "--strategy", "xla",
        "--http", "0", "--queue-depth", "4",
    ])
    assert gen.shape == (3, 3)


def test_data_shardings_replicates_scalar_leaves():
    """0-d leaves (step counters, scalar metrics) used to raise IndexError
    (``spec[batch_axis]`` on an empty spec list); they replicate now."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"tokens": jnp.zeros((4, 8), jnp.int32),
            "step": jnp.zeros((), jnp.int32),
            "flag": jnp.zeros((3,), jnp.int32)}
    out = shd.data_shardings(tree, mesh)
    assert out["step"].spec == P()
    assert out["tokens"].spec == P("data", None)
    # batch_axis past a leaf's rank also degrades to replicated
    out1 = shd.data_shardings({"x": jnp.zeros((5,))}, mesh, batch_axis=1)
    assert out1["x"].spec == P()
