"""CLI driver smokes: train + serve on reduced configs (the example paths)."""
import jax.numpy as jnp

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_train_cli_reduced(tmp_path):
    losses = train_main([
        "--arch", "internvl2-1b", "--reduced", "--steps", "4",
        "--batch", "2", "--seq", "16",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    ])
    assert len(losses) == 4 and all(jnp.isfinite(l) for l in losses)


def test_serve_cli_quantized_fused(tmp_path):
    gen = serve_main([
        "--arch", "olmoe-1b-7b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "4", "--strategy", "xla",
    ])
    assert gen.shape == (2, 4)
    assert int(gen.min()) >= 0


def test_serve_cli_encdec(tmp_path):
    gen = serve_main([
        "--arch", "whisper-small", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "3", "--strategy", "xla",
    ])
    assert gen.shape == (2, 3)
