"""Autotuner invariants: VMEM fit, validity, and sane regime behavior.

(Deterministic parametrized sweep — formerly hypothesis-driven.)
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.kernels import ref
from repro.kernels.autotune import VMEM_BUDGET, autotune_w4a16, vmem_working_set
from repro.kernels.w4a16_fused import w4a16_fused


@pytest.mark.parametrize(
    "M,N,K", itertools.product([1, 8, 64, 512],
                               [1024, 2048, 8192],
                               [2048, 4096, 16384]))
def test_autotune_fits_vmem_and_divides(M, N, K):
    bm, bn, bk, s = autotune_w4a16(M, N, K, group=128)
    assert vmem_working_set(bm, bn, bk, 128) <= VMEM_BUDGET
    assert N % bn == 0 and (K // s) % bk == 0 and K % s == 0
    assert bk % 128 == 0 or 128 % bk == 0


def test_autotune_split_k_regimes():
    """TPU-adapted Split-K: with int4 weights the HBM term dominates every
    realistic shape and is invariant in S, while a chip has only 2 parallel
    units (megacore), not
    Ascend's 32 cores, so intra-chip Split-K only pays when a single
    output tile leaves a core idle on a compute-bound GEMM; memory-bound
    decode GEMMs are traffic-invariant in S (the paper's occupancy win
    moves to mesh-level K-sharding — see DESIGN.md)."""
    for (M, N, K) in [(128, 128, 65536), (1, 1024, 16384),
                      (2048, 8192, 4096)]:
        _, _, _, s = autotune_w4a16(M, N, K)
        assert s == 1, (M, N, K, s)
    # the Ascend-faithful heuristic (32-core occupancy) DOES split there:
    from repro.kernels.ops import choose_split_k
    assert choose_split_k(1, 1024, 16384) >= 2


def test_autotuned_blocks_run_correctly():
    M, N, K = 8, 1024, 4096
    bm, bn, bk, s = autotune_w4a16(M, N, K)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N), jnp.float32)
    x = jax.random.normal(key, (M, K), jnp.float32)
    qt = quantize(w, group_size=128)
    got = w4a16_fused(x, qt, split_k=s, block_m=bm, block_n=bn, block_k=bk,
                      interpret=True)
    want = ref.w4a16_ref(x, qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
