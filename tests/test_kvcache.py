"""Paged KV cache tests: block allocator (alloc/free/ref-count, CoW,
eviction, warm-prefix LRU retention), prefix-share keys, pool device ops,
KV quantization formats, and the end-to-end parity suite — chunked
prefill (the single prefill path, every architecture family)
token-identical to the ring engine, warm re-admits running zero prefill
steps, with and without prefix sharing, single-device and TP×DP on 8
fake devices (subprocess)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import serve_cache_len, serve_num_pages
from repro.core import quant
from repro.models import attention
from repro.models import transformer as T
from repro.runtime import kvcache as kvc
from repro.runtime.engine import Request, ServingEngine

ROOT = os.path.join(os.path.dirname(__file__), "..")
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# sizing (satellite: all cache sizing through configs.shapes)
# ---------------------------------------------------------------------------

def test_serve_cache_len_page_rounding():
    vlm = configs.get_reduced("internvl2-1b")            # vision_prefix=8
    assert serve_cache_len(vlm, 8, 4) == 20
    assert serve_cache_len(vlm, 8, 4, 8) == 24           # page multiple
    swa = configs.get_reduced("h2o-danube-1.8b")         # window=16
    assert serve_cache_len(swa, 30, 10, 16) == 16
    assert serve_cache_len(swa, 30, 10, 5) == 20         # window rounds up


def test_serve_num_pages_worst_case():
    cfg = configs.get_reduced("olmoe-1b-7b")
    # cache_len(8,4)=12 → 3 pages of 4 per slot, ×2 slots + null block
    assert serve_num_pages(cfg, 8, 4, page_size=4, max_batch=2) == 7


def test_engine_sizing_routes_through_shapes():
    cfg = dataclasses.replace(configs.get_reduced("olmoe-1b-7b"),
                              w4a16_strategy="xla")
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                        max_new_tokens=4, page_size=4)
    assert eng.cache_len == serve_cache_len(cfg, 8, 4, 4)
    assert eng.num_pages == serve_num_pages(cfg, 8, 4, page_size=4,
                                            max_batch=2)


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_refcount():
    a = kvc.BlockAllocator(5, 4)                  # blocks 1..4 usable
    b1, b2 = a.alloc(), a.alloc()
    assert b1 != b2 and kvc.NULL_BLOCK not in (b1, b2)
    assert a.pages_in_use == 2 and a.pages_free == 2
    a.incref(b1)
    assert a.refcount(b1) == 2
    assert not a.decref(b1)                       # still referenced
    assert a.decref(b1)                           # freed now
    assert a.pages_in_use == 1 and a.pages_free == 3
    # eviction returns pages: freed block is allocatable again
    seen = {a.alloc() for _ in range(3)}
    assert b1 in seen
    with pytest.raises(RuntimeError, match="exhausted"):
        a.alloc()


def test_allocator_share_publish_cow():
    a = kvc.BlockAllocator(6, 4)
    bid = a.alloc()
    a.publish("k0", bid)
    assert a.peek("k0") == bid and a.refcount(bid) == 1   # peek: no ref
    assert a.lookup("k0") == bid and a.refcount(bid) == 2
    # CoW: writer gets a private block, shared one keeps its key
    new = a.cow(bid)
    assert new != bid and a.refcount(bid) == 1 and a.refcount(new) == 1
    assert a.peek("k0") == bid
    with pytest.raises(ValueError, match="not shared"):
        a.cow(bid)
    # freeing the published block drops its index entry
    assert a.decref(bid)
    assert a.peek("k0") is None


def test_allocator_warm_retention_adopt_and_repark():
    """A published block decref'd to 0 under a warm budget parks instead
    of freeing; lookup adopts it back to live (ref 1) with its first-token
    meta intact; releasing again re-parks it."""
    a = kvc.BlockAllocator(6, 4, warm_bytes=4 * 8, block_bytes=8)
    bid = a.alloc()
    a.publish("k0", bid)
    a.set_meta("k0", 42)
    assert not a.decref(bid)                      # retained, not freed
    assert a.is_warm(bid) and a.warm_pages == 1
    assert a.pages_in_use == 0                    # warm ≠ live
    got = a.lookup("k0")
    assert got == bid and not a.is_warm(bid) and a.refcount(bid) == 1
    assert a.meta("k0") == 42
    assert not a.decref(bid)                      # parks again
    assert a.is_warm(bid)
    # zero budget → plain free semantics (and the key drops)
    z = kvc.BlockAllocator(6, 4)
    b2 = z.alloc()
    z.publish("k0", b2)
    assert z.decref(b2) and z.peek("k0") is None


def test_allocator_warm_budget_never_exceeded():
    """Churning publishes/releases through a 2-block byte budget: the warm
    set never overflows it, and overflow evicts coldest-first."""
    a = kvc.BlockAllocator(10, 4, warm_bytes=2 * 8, block_bytes=8)
    parked = []
    for i in range(6):
        bid = a.alloc()
        a.publish(f"k{i}", bid)
        a.decref(bid)
        parked.append(bid)
        assert a.warm_bytes_used <= a.warm_bytes
    assert a.warm_pages == 2
    # the two survivors are the warmest (most recently parked)
    assert all(a.is_warm(b) for b in parked[-2:])
    assert not any(a.is_warm(b) for b in parked[:-2])
    # evicted ids surfaced for device-side tag wipes, oldest first
    assert a.take_reclaimed() == parked[:-2]
    assert a.take_reclaimed() == []


def test_allocator_alloc_reclaims_coldest_warm_block():
    """When the free list runs dry, alloc() steals the coldest warm block
    rather than raising — warm pages are capacity, not a leak."""
    a = kvc.BlockAllocator(4, 4, warm_bytes=8 * 8, block_bytes=8)
    b1, b2, b3 = a.alloc(), a.alloc(), a.alloc()  # pool exhausted (3 usable)
    a.publish("k1", b1)
    a.publish("k2", b2)
    a.decref(b1)
    a.decref(b2)                                  # b1 older than b2
    assert a.pages_free == 0 and a.warm_pages == 2
    fresh = a.alloc()                             # reclaims b1 (coldest)
    assert fresh == b1 and not a.is_warm(b1)
    assert a.peek("k1") is None and a.peek("k2") == b2
    assert a.take_reclaimed() == [b1]
    a.decref(b3)                                  # unpublished → plain free


def test_allocator_purge_warm_empties_pool():
    """purge_warm at run boundaries returns every warm page to the free
    list: pool exactly empty, all ids surfaced for tag wipes."""
    a = kvc.BlockAllocator(8, 4, warm_bytes=16 * 8, block_bytes=8)
    for i in range(5):
        bid = a.alloc()
        a.publish(f"k{i}", bid)
        a.decref(bid)
    assert a.warm_pages == 5
    purged = a.purge_warm()
    assert len(purged) == 5 and a.warm_pages == 0
    assert a.pages_in_use == 0
    assert a.pages_free == a.num_blocks - 1       # exactly empty
    assert sorted(a.take_reclaimed()) == sorted(purged)
    assert all(a.peek(f"k{i}") is None for i in range(5))


def test_page_keys_prefix_property():
    units = [bytes([i]) for i in range(10)]
    full, partial = kvc.page_keys(units, 4)
    assert len(full) == 2 and partial is not None and partial[1] == 2
    # same prefix → same keys; divergence changes every later key
    full2, _ = kvc.page_keys(units[:8], 4)
    assert full2 == full
    mutated = list(units)
    mutated[5] = b"\xff"
    fm, _ = kvc.page_keys(mutated, 4)
    assert fm[0] == full[0] and fm[1] != full[1]
    # page keys commit to length too (b"ab"+b"c" != b"a"+b"bc")
    fa, _ = kvc.page_keys([b"ab", b"c", b"x", b"y"], 4)
    fb, _ = kvc.page_keys([b"a", b"bc", b"x", b"y"], 4)
    assert fa != fb


# ---------------------------------------------------------------------------
# pool device ops
# ---------------------------------------------------------------------------

def _pool(nb=4, ps=2, h=1, d=4, fmt="kv_fp16"):
    return kvc.init_pool(nb, ps, h, d, jnp.float32, fmt)


def test_paged_insert_gather_roundtrip():
    fmt = quant.get_kv_format("kv_fp16")
    pool = _pool()
    tables = jnp.asarray([[1, 2], [3, -1]], jnp.int32)    # 2 slots, T=2
    k = jnp.ones((2, 1, 4)) * jnp.asarray([1.0, 2.0])[:, None, None]
    pool = kvc.paged_insert(pool, tables, k, k, jnp.asarray([0, 1]),
                            cache_len=4, fmt=fmt)
    win = kvc.gather_window(pool, tables, fmt=fmt, out_dtype=jnp.float32)
    assert win.k.shape == (2, 4, 1, 4)
    assert int(win.pos[0, 0]) == 0 and float(win.k[0, 0, 0, 0]) == 1.0
    assert int(win.pos[1, 1]) == 1 and float(win.k[1, 1, 0, 0]) == 2.0
    assert np.all(np.asarray(win.pos[0, 1:]) == -1)
    # unmapped table entries gather the null block: all masked
    assert np.all(np.asarray(win.pos[1, 2:]) == -1)


def test_paged_insert_inactive_slot_hits_null_block():
    fmt = quant.get_kv_format("kv_fp16")
    pool = _pool()
    tables = jnp.asarray([[-1, -1]], jnp.int32)           # inactive slot
    k = jnp.full((1, 1, 4), 7.0)
    pool = kvc.paged_insert(pool, tables, k, k, jnp.asarray([3]),
                            cache_len=4, fmt=fmt)
    # the write was redirected into block 0 with a -1 tag: harmless
    assert np.all(np.asarray(pool.page_pos) == -1)


def test_copy_and_reset_blocks():
    fmt = quant.get_kv_format("kv_fp16")
    pool = _pool()
    tables = jnp.asarray([[1, -1]], jnp.int32)
    k = jnp.full((1, 1, 4), 3.0)
    pool = kvc.paged_insert(pool, tables, k, k, jnp.asarray([0]),
                            cache_len=4, fmt=fmt)
    pool = kvc.copy_blocks(pool, 1, 2)
    assert float(pool.k_pool[2, 0, 0, 0]) == 3.0
    assert int(pool.page_pos[2, 0]) == 0
    pool = kvc.reset_blocks(pool, [1])
    assert np.all(np.asarray(pool.page_pos[1]) == -1)     # wiped
    assert int(pool.page_pos[2, 0]) == 0                  # copy untouched


def test_kv8_quantize_roundtrip():
    fmt = quant.get_kv_format("kv8_channel")
    x = jax.random.normal(KEY, (6, 2, 8), jnp.float32) * 3.0
    q, s = quant.kv_quantize(x, fmt)
    assert q.dtype == jnp.int8 and s.shape == (6, 2)
    back = quant.kv_dequantize(q, s, fmt, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-6
    assert np.all(err <= bound)
    # passthrough format stores verbatim
    fp = quant.get_kv_format("kv_fp16")
    q2, s2 = quant.kv_quantize(x, fp)
    assert s2 is None and q2 is x


def test_kv_format_registry_validation():
    with pytest.raises(ValueError, match="unknown KV-cache format"):
        quant.get_kv_format("kv4_magic")
    with pytest.raises(ValueError, match="per-head"):
        quant.KVFormat("bad", bits=8, scale_granularity="none")
    from repro.launch.serve import validate_kv_format
    assert validate_kv_format("kv8_channel", "w4a16_g128",
                              paged=True) == "kv8_channel"
    with pytest.raises(ValueError, match="paged"):
        validate_kv_format("kv8_channel", "w4a16_g128", paged=False)
    with pytest.raises(ValueError, match="unknown KV-cache format"):
        validate_kv_format("nope", "w4a16_g128", paged=True)
    with pytest.raises(ValueError, match="unknown quantization format"):
        validate_kv_format("kv_fp16", "w3a3", paged=True)


# ---------------------------------------------------------------------------
# end-to-end parity suite: paged engine ≡ ring engine
# ---------------------------------------------------------------------------

def _params(cfg, quantized=True):
    p = T.init_params(KEY, cfg)
    return T.quantize_params(p, cfg, min_size=0) if quantized else p


def _requests(cfg, n, P, G, *, same_prompt=False, arrival_every=0):
    toks = jax.random.randint(KEY, (n, P), 0, cfg.vocab_size)
    reqs = []
    for i in range(n):
        kw = {}
        if cfg.vision_prefix:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, 0 if same_prompt else i),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            kw["audio_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, i),
                (cfg.encoder_seq, cfg.d_model), cfg.dtype)
        reqs.append(Request(
            rid=i, prompt=toks[0] if same_prompt else toks[i],
            max_new_tokens=G, arrival_step=i * arrival_every, **kw))
    return reqs


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "internvl2-1b"])
@pytest.mark.parametrize("chunk", [None, 3])
def test_paged_engine_parity(arch, chunk):
    """Paged decode (whole-prompt and chunked prefill) is token-identical
    to the pre-refactor ring engine — the tentpole acceptance."""
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              w4a16_strategy="xla")
    P, G, n = 8, 4, 2
    params = _params(cfg)
    paged = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                          max_new_tokens=G, page_size=4,
                          prefill_chunk=chunk)
    ring = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=paged.cache_len)
    want = ring.run(_requests(cfg, n, P, G)).results
    got = paged.run(_requests(cfg, n, P, G)).results
    assert got == want


@pytest.mark.parametrize("family_arch", ["whisper-small", "hymba-1.5b",
                                         "olmoe-1b-7b", "rwkv6-7b"])
def test_paged_engine_parity_all_families(family_arch):
    """Recurrent / enc-dec / MoE families prefill through the same chunked
    path as everyone else (carries threaded per chunk) and decode
    token-identically to the ring engine — there is no whole-prompt
    fallback any more. MoE needs full expert capacity for exact parity
    (capacity dropping is routing-batch-shaped; see prefill_chunk_step)."""
    cfg = dataclasses.replace(configs.get_reduced(family_arch),
                              w4a16_strategy="xla")
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=64.0)
    P, G, n = 8, 3, 2
    params = _params(cfg)
    for chunk in (None, 3):
        paged = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                              max_new_tokens=G, page_size=4,
                              prefill_chunk=chunk)
        ring = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                             max_new_tokens=G, paged=False,
                             cache_len=paged.cache_len)
        want = ring.run(_requests(cfg, n, P, G)).results
        got = paged.run(_requests(cfg, n, P, G)).results
        assert got == want, f"chunk={chunk}"


@pytest.mark.parametrize("chunk,arrival,min_saved", [
    (None, 0, 3),   # whole-prompt: peer publishes at admit → share all
    (4, 0, 1),      # lockstep chunked: adopt pages the peer just produced
    (3, 2, 1),      # staggered chunked: catch-up via share-ahead
])
def test_prefix_sharing_reduces_pages_and_keeps_tokens(chunk, arrival,
                                                       min_saved):
    """Identical prompts across slots: outputs stay token-identical to the
    ring engine while pages-in-use drop measurably (shared blocks)."""
    cfg = dataclasses.replace(configs.get_reduced("internvl2-1b"),
                              w4a16_strategy="xla")
    P, G, n = 8, 4, 2
    params = _params(cfg)
    paged = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                          max_new_tokens=G, page_size=4,
                          prefill_chunk=chunk)
    ring = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=paged.cache_len)
    shared = paged.run(_requests(cfg, n, P, G, same_prompt=True,
                                 arrival_every=arrival))
    want = ring.run(_requests(cfg, n, P, G, same_prompt=True,
                              arrival_every=arrival)).results
    assert shared.results == want
    # distinct prompts for comparison
    paged2 = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                           max_new_tokens=G, page_size=4,
                           prefill_chunk=chunk)
    distinct = paged2.run(_requests(cfg, n, P, G, arrival_every=arrival))
    assert shared.peak_pages <= distinct.peak_pages - min_saved


def test_cow_on_divergent_write():
    """Two slots share a partial prompt page; the first decode write into
    it must copy-on-write — generations diverge, prompt context doesn't."""
    # full expert capacity: chunked prefill's padded routing batch must
    # not drop different tokens than the ring reference (MoE note in
    # prefill_chunk_step)
    cfg = dataclasses.replace(configs.get_reduced("olmoe-1b-7b"),
                              w4a16_strategy="xla",
                              moe_capacity_factor=64.0)
    P, G, n = 6, 4, 2                     # 6 % 4 → partial last page
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                        max_new_tokens=G, page_size=4)
    reqs = _requests(cfg, n, P, G, same_prompt=True)
    rep = eng.run(reqs)
    # identical prompts → identical greedy generations, from two slots
    # whose tables started out aliasing the same partial block
    assert rep.results[0] == rep.results[1]
    ring = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=eng.cache_len)
    assert rep.results == ring.run(
        _requests(cfg, n, P, G, same_prompt=True)).results
    # and the divergent writes forced private copies: more pages live at
    # peak than the shared-prefix floor (2 shared pages: 1 full + 1 CoW'd)
    assert rep.peak_pages > 1


def test_paged_slot_reuse_no_leak():
    """Continuous batching with more requests than slots: freed blocks are
    recycled across requests without leaking stale context."""
    # full expert capacity — same MoE chunk-vs-ring caveat as above
    cfg = dataclasses.replace(configs.get_reduced("olmoe-1b-7b"),
                              w4a16_strategy="xla",
                              moe_capacity_factor=64.0)
    P, G, n = 8, 3, 5
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                        max_new_tokens=G, page_size=4)
    report = eng.run(_requests(cfg, n, P, G, arrival_every=1))
    assert sorted(report.results) == list(range(n))
    assert all(len(t) == G for t in report.results.values())
    # after the run every block is back in the free pool
    assert eng.alloc.pages_in_use == 0
    assert eng.alloc.pages_free == eng.num_pages - 1
    # and matches the ring engine's outputs request-for-request
    ring = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=eng.cache_len)
    assert report.results == ring.run(
        _requests(cfg, n, P, G, arrival_every=1)).results


# ---------------------------------------------------------------------------
# warm prefix cache (engine level)
# ---------------------------------------------------------------------------

def test_warm_prefix_readmit_runs_zero_prefill_steps():
    """A returning page-aligned prompt under a nonzero warm budget adopts
    its whole chain + cached first token at admit: zero chunk steps, one
    warm hit, tokens identical to both the cold engine and the ring
    reference — the retention acceptance criterion."""
    cfg = dataclasses.replace(configs.get_reduced("starcoder2-7b"),
                              w4a16_strategy="xla")
    P, G = 8, 3
    params = _params(cfg)

    def reqs():
        # request 1 re-sends request 0's prompt long after its release
        return _requests(cfg, 2, P, G, same_prompt=True, arrival_every=12)

    warm = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                         max_new_tokens=G, page_size=4, prefill_chunk=4,
                         warm_cache_mb=1.0)
    wrep = warm.run(reqs())
    cold = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                         max_new_tokens=G, page_size=4, prefill_chunk=4)
    crep = cold.run(reqs())
    ring = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=warm.cache_len)
    want = ring.run(reqs()).results
    assert wrep.results == want and crep.results == want
    assert wrep.warm_hits == 1 and wrep.warm_misses == 1
    assert crep.warm_hits == 0 and crep.warm_misses == 0
    # the re-admit skipped ALL ceil(P/chunk)=2 of its chunk steps (one of
    # which the cold engine overlaps with the admit step)
    assert wrep.prefill_steps_saved == 2
    assert wrep.steps < crep.steps
    # run boundaries stay cold: start() purges the warm set
    assert warm.run(reqs()).results == want


def test_warm_budget_is_respected_and_counts_misses():
    """Distinct prompts churning through a one-chain budget: retention
    never exceeds warm_bytes, every admit is a miss, and the engine ends
    with the warm pages still accounted (not leaked, not live)."""
    cfg = dataclasses.replace(configs.get_reduced("starcoder2-7b"),
                              w4a16_strategy="xla")
    P, G, n = 8, 3, 3
    params = _params(cfg)
    probe = ServingEngine(cfg, params, max_batch=1, max_prompt_len=P,
                          max_new_tokens=G, page_size=4)
    one_chain_mb = probe.alloc.block_bytes * (P // 4) / (1 << 20)
    eng = ServingEngine(cfg, params, max_batch=1, max_prompt_len=P,
                        max_new_tokens=G, page_size=4, prefill_chunk=4,
                        warm_cache_mb=one_chain_mb)
    rep = eng.run(_requests(cfg, n, P, G, arrival_every=1))
    assert sorted(rep.results) == list(range(n))
    assert rep.warm_hits == 0 and rep.warm_misses == n
    assert eng.alloc.warm_bytes_used <= eng.alloc.warm_bytes
    assert eng.alloc.warm_pages <= P // 4       # at most one chain parked
    assert eng.alloc.pages_in_use == 0
    assert (eng.alloc.pages_free + eng.alloc.warm_pages
            == eng.num_pages - 1)


def test_kv8_channel_engine_close():
    """kv8_channel decode stays close to fp16 KV: same report shape, and
    per-step logits dominated by the quantization error bound (token
    streams may legitimately diverge on a random tiny model)."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    P, G, n = 8, 4, 2
    params = _params(cfg)
    for chunk in (None, 3):
        eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                            max_new_tokens=G, page_size=4,
                            prefill_chunk=chunk, kv_format="kv8_channel")
        rep = eng.run(_requests(cfg, n, P, G))
        assert sorted(rep.results) == list(range(n))
        assert all(len(t) == G for t in rep.results.values())


def test_chunked_prefill_wrapping_prompt_parity():
    """SWA arch with a prompt longer than the window: chunk offsets wrap
    the logical ring and overwrite its oldest entries — the chunk step
    must gather the window *before* scattering (its earliest queries
    still attend those entries) and still match the ring engine."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")         # window 16
    P, G = 40, 4
    params = _params(cfg)
    toks = jax.random.randint(KEY, (1, P), 0, cfg.vocab_size)
    for chunk, ps in ((8, 8), (7, 4)):
        eng = ServingEngine(cfg, params, max_batch=1, max_prompt_len=P,
                            max_new_tokens=G, page_size=ps,
                            prefill_chunk=chunk)
        rep = eng.run([Request(rid=0, prompt=toks[0], max_new_tokens=G)])
        ring = ServingEngine(cfg, params, max_batch=1, max_prompt_len=P,
                             max_new_tokens=G, paged=False,
                             cache_len=eng.cache_len)
        want = ring.run([Request(rid=0, prompt=toks[0],
                                 max_new_tokens=G)]).results
        assert rep.results == want


def test_encdec_same_prompt_different_audio_does_not_share():
    """Decoder K/V depend on the audio through cross-attention: identical
    decoder prompts over different audio must not share pages (the page
    keys are seeded with the audio content) — and identical audio still
    shares."""
    cfg = dataclasses.replace(configs.get_reduced("whisper-small"),
                              w4a16_strategy="xla")
    P, G, n = 8, 4, 2
    params = _params(cfg)
    toks = jax.random.randint(KEY, (1, P), 0, cfg.vocab_size)

    def reqs(same_audio):
        return [Request(
            rid=i, prompt=toks[0], max_new_tokens=G,
            audio_embeds=jax.random.normal(
                jax.random.fold_in(KEY, 0 if same_audio else i),
                (cfg.encoder_seq, cfg.d_model), cfg.dtype))
            for i in range(n)]

    eng = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                        max_new_tokens=G, page_size=4)
    ring = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=eng.cache_len)
    rep = eng.run(reqs(same_audio=False))
    assert rep.results == ring.run(reqs(same_audio=False)).results
    # identical audio + prompt: pages shared, tokens still right
    eng2 = ServingEngine(cfg, params, max_batch=n, max_prompt_len=P,
                        max_new_tokens=G, page_size=4)
    rep2 = eng2.run(reqs(same_audio=True))
    assert rep2.results == ring.run(reqs(same_audio=True)).results
    assert rep2.peak_pages < rep.peak_pages


def test_wrapped_decode_unpublishes_recycled_prompt_pages():
    """A refcount-1 owner's wrapped decode overwrites its own published
    prompt pages in place; the prefix index must drop those keys or a
    later identical prompt adopts destroyed content (wrong tokens)."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")         # window 16
    P, G = 14, 10                       # pos0+G = 24 > cache_len: wraps
    params = _params(cfg)
    toks = jax.random.randint(KEY, (1, P), 0, cfg.vocab_size)

    def reqs():
        return [Request(rid=0, prompt=toks[0], max_new_tokens=G),
                Request(rid=1, prompt=toks[0], max_new_tokens=G,
                        arrival_step=6)]

    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                        max_new_tokens=G, page_size=4)
    ring = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=eng.cache_len)
    assert eng.run(reqs()).results == ring.run(reqs()).results


def test_tight_pool_defers_admit_instead_of_crashing():
    """A pool too small for two zero-sharing lifetimes: the admit gate
    must account for wrap-time CoW of every shared page (no sharing
    discount when decode wraps) and defer the second request rather than
    exhausting the allocator mid-serve."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    P, G = 14, 8                        # wraps; pages_slot=4
    params = _params(cfg)
    toks = jax.random.randint(KEY, (1, P), 0, cfg.vocab_size)

    def reqs():
        return [Request(rid=0, prompt=toks[0], max_new_tokens=G),
                Request(rid=1, prompt=toks[0], max_new_tokens=G,
                        arrival_step=1)]

    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                        max_new_tokens=G, page_size=4, num_pages=6)
    rep = eng.run(reqs())
    assert sorted(rep.results) == [0, 1]
    ring = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=eng.cache_len)
    assert rep.results == ring.run(reqs()).results


def test_engine_refuses_undersized_pool():
    """A pool that cannot hold even one slot's window would make the
    admit gate wait forever — refused at construction instead."""
    cfg = dataclasses.replace(configs.get_reduced("olmoe-1b-7b"),
                              w4a16_strategy="xla")
    with pytest.raises(ValueError, match="null"):
        ServingEngine(cfg, _params(cfg), max_batch=1, max_prompt_len=8,
                      max_new_tokens=4, page_size=8, num_pages=2)


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt admitted mid-run is prefilled in chunks across steps
    while earlier slots keep decoding — decode is never stalled for the
    whole prompt, and outputs still match the ring engine."""
    cfg = dataclasses.replace(configs.get_reduced("h2o-danube-1.8b"),
                              w4a16_strategy="xla")
    P, G = 12, 6
    params = _params(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                        max_new_tokens=G, page_size=4, prefill_chunk=4)
    reqs = _requests(cfg, 2, P, G, arrival_every=2)
    rep = eng.run(reqs)
    ring = ServingEngine(cfg, params, max_batch=2, max_prompt_len=P,
                         max_new_tokens=G, paged=False,
                         cache_len=eng.cache_len)
    assert rep.results == ring.run(
        _requests(cfg, 2, P, G, arrival_every=2)).results
    # request 1 arrives at step 2 with a 12-token prompt and chunk=4: its
    # prefill spans ≥3 engine steps, during which slot 0 kept decoding
    decoded_during_admit = [r["active"] for r in rep.step_records
                            if 2 <= r["step"] < 5]
    assert decoded_during_admit and all(a >= 1 for a in decoded_during_admit)


# ---------------------------------------------------------------------------
# multi-device parity (subprocess with 8 fake CPU devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro import configs
from repro.kernels import planning
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.runtime.engine import Request, ServingEngine

out = {}
P, G, R, SLOTS = 8, 5, 3, 2


def build_requests(cfg, key, same):
    toks = jax.random.randint(key, (R, P), 0, cfg.vocab_size)
    reqs = []
    for i in range(R):
        kw = {}
        if cfg.vision_prefix:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 0 if same else i),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        reqs.append(Request(rid=i, prompt=toks[0] if same else toks[i],
                            max_new_tokens=G, arrival_step=i, **kw))
    return reqs


def run_engine(cfg, params, mesh, reqs, **kw):
    eng = ServingEngine(cfg, params, mesh=mesh, max_batch=SLOTS,
                        max_prompt_len=P, max_new_tokens=G, page_size=4,
                        **kw)
    rep = eng.run(reqs)
    return {str(k): v for k, v in sorted(rep.results.items())}, rep


for arch in ("h2o-danube-1.8b", "internvl2-1b"):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
    for same in (False, True):
        planning.PLAN_CACHE.clear()
        reqs = build_requests(cfg, key, same)
        single, _ = run_engine(cfg, params, None, reqs, prefill_chunk=3)
        mesh = make_local_mesh(data=2, model=4)
        planning.PLAN_CACHE.clear()
        sharded, rep = run_engine(cfg, params, mesh,
                                  build_requests(cfg, key, same),
                                  prefill_chunk=3)
        tag = f"{arch}/share={same}"
        out[tag + "/match"] = sharded == single
        if same:
            planning.PLAN_CACHE.clear()
            mesh2 = make_local_mesh(data=1, model=4)
            distinct, rep_d = run_engine(cfg, params, mesh2,
                                         build_requests(cfg, key, False),
                                         prefill_chunk=3)
            out[tag + "/fewer_pages"] = rep.peak_pages < rep_d.peak_pages
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_paged_engine_parity():
    """TP=4 x DP=2 paged engine decode (chunked prefill, with and without
    prefix sharing) is token-identical to single-device paged decode on
    danube + internvl2, and sharing reduces peak pages on the mesh too."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out and all(out.values()), {k: v for k, v in out.items() if not v}


WARM_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro import configs
from repro.kernels import planning
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.runtime.engine import Request, ServingEngine

P, G = 8, 4
cfg = configs.get_reduced("h2o-danube-1.8b")     # w4a16_strategy="auto"
key = jax.random.PRNGKey(0)
params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
toks = jax.random.randint(key, (P,), 0, cfg.vocab_size)


def reqs():
    # the same prompt returns long after the first holder released it
    return [Request(rid=0, prompt=toks, max_new_tokens=G),
            Request(rid=1, prompt=toks, max_new_tokens=G, arrival_step=14)]


def run(mesh):
    planning.PLAN_CACHE.clear()
    eng = ServingEngine(cfg, params, mesh=mesh, max_batch=2,
                        max_prompt_len=P, max_new_tokens=G, page_size=4,
                        prefill_chunk=4, warm_cache_mb=1.0)
    rep = eng.run(reqs())
    return {str(k): v for k, v in sorted(rep.results.items())}, rep


single, srep = run(None)
sharded, mrep = run(make_local_mesh(data=2, model=4))
out = {"match": sharded == single,
       "single_hit": srep.warm_hits == 1,
       "sharded_hit": mrep.warm_hits == 1,
       "sharded_saved": mrep.prefill_steps_saved >= 1,
       "sharded_fewer_steps": mrep.steps == srep.steps}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_warm_prefix_readmit_parity():
    """TP=4 x DP=2 warm re-admit: the returning prompt warm-hits on the
    mesh too, skips its prefill steps, and stays token-identical to the
    single-device warm engine."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", WARM_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out and all(out.values()), {k: v for k, v in out.items() if not v}
