"""QuantFormat registry + multi-format correctness.

Covers: the registry (builtins, registration, derived variants, JSON),
format-dispatched quantize/dequantize for W8A16 (per-channel int8) and
W4A8 (dynamic int8 activations), planner format filtering + the
strategy/format refusal error, per-format plan caching, checkpoint format
sidecars, and quantize_tree with a format name.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.quant import (
    QuantFormat,
    QuantizedTensor,
    available_formats,
    dequantize,
    get_format,
    quantize,
    quantize_activations_int8,
    register_format,
    resolve_format,
    w4a8_matmul_ref,
    w4a16_matmul_ref,
)
from repro.kernels import planning
from repro.kernels.planning import (
    KernelPlan, MatmulProblem, execute, plan_matmul, strategies_for_format,
)

KEY = jax.random.PRNGKey(0)


def _w(K=256, N=64, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(K, N)).astype(np.float32))


def _x(M=4, K=256, seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(M, K)).astype(np.float32))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_formats_registered():
    names = available_formats()
    assert len(names) >= 3
    for name in ("w4a16_g128", "w8a16_channel", "w4a8_g128"):
        assert name in names
        assert get_format(name).name == name
    assert get_format("w4a16_g128").weight_bits == 4
    assert get_format("w8a16_channel").scale_granularity == "channel"
    assert get_format("w4a8_g128").quantized_activations


def test_format_json_round_trip():
    fmt = get_format("w4a8_g128")
    blob = json.dumps(fmt.to_dict())
    assert QuantFormat.from_dict(json.loads(blob)) == fmt
    # resolve accepts name / object / descriptor dict / None (the default)
    assert resolve_format("w4a8_g128") is fmt
    assert resolve_format(fmt) is fmt
    assert resolve_format(fmt.to_dict()) == fmt
    assert resolve_format(None).name == quant.DEFAULT_FORMAT


def test_register_and_conflict():
    fmt = QuantFormat(name="_test_w8a16_g64", weight_bits=8,
                      packing="int8_rows", scale_granularity="group",
                      group_size=64)
    try:
        assert register_format(fmt) is fmt
        assert get_format("_test_w8a16_g64") is fmt
        register_format(fmt)                       # identical re-register: ok
        clash = dataclasses.replace(fmt, group_size=32)
        with pytest.raises(ValueError, match="already registered"):
            register_format(clash)
        register_format(clash, overwrite=True)
        assert get_format("_test_w8a16_g64").group_size == 32
    finally:
        quant._FORMAT_REGISTRY.pop("_test_w8a16_g64", None)


def test_unknown_format_raises_with_listing():
    with pytest.raises(ValueError, match="unknown quantization format"):
        get_format("w2a2_nope")


def test_derived_variants_register_on_demand():
    g64 = get_format("w4a16_g128").with_group_size(64)
    assert g64.name == "w4a16_g64" and g64.group_size == 64
    assert "w4a16_g64" in available_formats()
    asym = g64.with_symmetric(False)
    assert asym.name == "w4a16_g64_asym" and not asym.symmetric
    assert asym.with_symmetric(True) is g64 or \
        asym.with_symmetric(True).name == "w4a16_g64"
    # channel granularity has no groups: with_group_size is a no-op
    ch = get_format("w8a16_channel")
    assert ch.with_group_size(64) is ch


def test_format_validation():
    with pytest.raises(ValueError, match="packing"):
        QuantFormat(name="bad", packing="int3_whatever")
    with pytest.raises(ValueError, match="4-bit"):
        QuantFormat(name="bad", weight_bits=8, packing="int4_pairs_k")
    with pytest.raises(ValueError, match="granularity"):
        QuantFormat(name="bad", scale_granularity="row")


def test_legacy_constructor_infers_format():
    """Pre-format call sites (bare group_size) get the W4A16-family shim."""
    w = _w()
    qt = quantize(w, group_size=64)
    assert qt.format.name == "w4a16_g64"
    raw = QuantizedTensor(qt.packed, qt.scales, None, 64, jnp.float32)
    assert raw.format.name == "w4a16_g64"
    asym = quantize(w, group_size=64, symmetric=False)
    raw2 = QuantizedTensor(asym.packed, asym.scales, asym.zeros, 64,
                           jnp.float32)
    assert raw2.format.name == "w4a16_g64_asym"


# ---------------------------------------------------------------------------
# w8a16: per-channel int8 weights
# ---------------------------------------------------------------------------

def test_w8a16_quantize_dequantize_error_bound():
    w = _w()
    qt = quantize(w, "w8a16_channel")
    assert qt.packed.shape == w.shape and qt.packed.dtype == jnp.int8
    assert qt.scales.shape == (1, w.shape[1])
    assert qt.group_size == w.shape[0]          # one scale row spans K
    bound = np.asarray(quant.quantization_error_bound(qt))  # (1, N)
    err = np.abs(np.asarray(dequantize(qt)) - np.asarray(w))
    assert (err <= bound * 1.001 + 1e-6).all()
    # int8 per-channel is much tighter than int4 group-wise
    err4 = np.abs(np.asarray(dequantize(quantize(w, group_size=128)))
                  - np.asarray(w))
    assert err.mean() < err4.mean() / 4


def test_w8a16_matmul_through_planner():
    w, x = _w(), _x()
    qt = quantize(w, "w8a16_channel")
    problem = MatmulProblem.from_operands(x, qt)
    assert problem.format == "w8a16_channel"
    plan = plan_matmul(problem, use_cache=False)
    assert plan.strategy in strategies_for_format("w8a16_channel")
    got = np.asarray(execute(plan, x, qt))
    want = np.asarray(x) @ np.asarray(dequantize(qt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# w4a8: dynamic int8 activations (LiquidGEMM-style) — acceptance criterion
# ---------------------------------------------------------------------------

def test_activation_quantization_error_bound():
    x = _x(M=8)
    xq, xs = quantize_activations_int8(x)
    assert xq.dtype == jnp.int8 and xs.shape == (8, 1)
    err = np.abs(np.asarray(xq, np.float32) * np.asarray(xs) - np.asarray(x))
    assert (err <= np.asarray(xs) / 2 * 1.001 + 1e-6).all()


@pytest.mark.parametrize("symmetric", [True, False])
def test_w4a8_matches_its_exact_decomposition(symmetric):
    """w4a8_matmul_ref == (xs * x_q) @ Dequant(W) up to fp32 association —
    the integer group accumulation reorders no math."""
    w, x = _w(), _x()
    qt = quantize(w, "w4a8_g128", symmetric=symmetric)
    got = np.asarray(w4a8_matmul_ref(x, qt))
    xq, xs = quantize_activations_int8(x)
    want = (np.asarray(xq, np.float32) * np.asarray(xs)) \
        @ np.asarray(dequantize(qt), np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_w4a8_close_to_float_reference_within_quant_bounds():
    """Acceptance: w4a8_g128 vs the dense float GEMM, bounded by the
    analytic weight + activation quantization error."""
    w, x = _w(K=512), _x(K=512)
    qt = quantize(w, "w4a8_g128")
    got = np.asarray(w4a8_matmul_ref(x, qt))
    dense = np.asarray(x) @ np.asarray(w)
    # |y - x@w| <= |x| @ wbound + xbound_row * sum_k |wdeq|  (elementwise)
    wbound = np.repeat(np.asarray(quant.quantization_error_bound(qt)),
                       qt.group_size, axis=0)               # (K, N)
    _, xs = quantize_activations_int8(x)
    xbound = np.asarray(xs) / 2                              # (M, 1)
    wdeq = np.abs(np.asarray(dequantize(qt), np.float32))
    bound = np.abs(np.asarray(x)) @ wbound + xbound * wdeq.sum(0)[None]
    assert (np.abs(got - dense) <= bound * 1.001 + 1e-4).all()
    # and the aggregate error stays at int4-noise level (the weight-quant
    # term dominates: ~s/2 per element ≈ 12-15% mean-relative on N(0,1)
    # data), i.e. W4A8 is no worse than W4A16 on the same weights
    rel = np.abs(got - dense).mean() / np.abs(dense).mean()
    w16 = np.asarray(w4a16_matmul_ref(x, quantize(w, group_size=128)))
    rel16 = np.abs(w16 - dense).mean() / np.abs(dense).mean()
    assert rel < 0.25, rel
    assert rel < rel16 * 1.25, (rel, rel16)


def test_w4a8_through_planner_and_leading_dims():
    w, x = _w(), _x(M=6)
    qt = quantize(w, "w4a8_g128")
    problem = MatmulProblem.from_operands(x, qt)
    plan = plan_matmul(problem, use_cache=False)
    assert plan.strategy == "w4a8_xla"
    got = execute(plan, x.reshape(2, 3, -1), qt)
    assert got.shape == (2, 3, qt.N)
    np.testing.assert_allclose(
        np.asarray(got).reshape(6, -1), np.asarray(w4a8_matmul_ref(x, qt)),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# planner format filtering — acceptance criterion
# ---------------------------------------------------------------------------

def test_plan_matmul_refuses_unsupported_strategy_format_pair():
    problem = MatmulProblem(M=4, N=64, K=256, format="w4a8_g128")
    for strategy in ("fused", "decoupled", "xla", "reference"):
        with pytest.raises(ValueError) as ei:
            plan_matmul(problem, strategy=strategy)
        msg = str(ei.value)
        assert "w4a8_g128" in msg and strategy in msg
        assert "w4a8_xla" in msg            # ...and tells you what would work
    # pallas strategies also refuse the float-act w8a16 (wrong packing)
    with pytest.raises(ValueError, match="does not support"):
        plan_matmul(MatmulProblem(M=4, N=64, K=256, group_size=256,
                                  format="w8a16_channel"), strategy="fused")


def test_execute_refuses_mismatched_plan():
    w, x = _w(), _x()
    qt = quantize(w, "w4a8_g128")
    with pytest.raises(ValueError, match="cannot execute"):
        execute(KernelPlan(strategy="fused"), x, qt)


def test_planner_refuses_shape_ineligible_w4a8():
    """K not group-divisible: no w4a8 strategy can execute, and unlike the
    W4A16 family there is no unconditional oracle — the planner must refuse
    at plan time, not hand back a plan that crashes at execute time."""
    problem = MatmulProblem(M=4, N=64, K=250, group_size=128,
                            format="w4a8_g128")
    with pytest.raises(ValueError, match="can execute this problem shape"):
        plan_matmul(problem, use_cache=False)


def test_planner_errors_when_no_strategy_supports_format():
    fmt = register_format(QuantFormat(
        name="_test_w8a16_orphan", weight_bits=8, packing="int8_rows",
        scale_granularity="tensor", group_size=0))
    try:
        with pytest.raises(ValueError, match="no registered strategy"):
            plan_matmul(MatmulProblem(M=4, N=64, K=256,
                                      format="_test_w8a16_orphan"),
                        use_cache=False)
    finally:
        quant._FORMAT_REGISTRY.pop("_test_w8a16_orphan", None)


def test_plans_cache_per_format():
    cache = planning.PlanCache()
    base = dict(M=4, N=64, K=256, group_size=128)
    a = MatmulProblem(**base, format="w4a16_g128")
    b = MatmulProblem(**base, format="w4a8_g128")
    assert a != b
    plan_matmul(a, cache=cache)
    plan_matmul(b, cache=cache)
    assert len(cache) == 2 and cache.hits == 0


def test_legacy_plan_cache_entries_get_default_format(tmp_path):
    """A pre-format plan-cache JSON (no "format" key) loads through the
    default-format shim and keys identically to new W4A16 problems."""
    old_entry = {
        "problem": {"M": 4, "N": 64, "K": 256, "group_size": 64,
                    "act_dtype": "float32", "out_dtype": "float32",
                    "has_zeros": False, "backend": "cpu", "batch": 1},
        "plan": KernelPlan(strategy="xla").to_dict(),
    }
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"version": 1, "plans": [old_entry]}))
    cache = planning.PlanCache()
    assert cache.load(str(path)) == 1
    new_key = MatmulProblem(M=4, N=64, K=256, group_size=64,
                            act_dtype="float32", out_dtype="float32",
                            format="w4a16_g64")
    assert cache.get(new_key) == KernelPlan(strategy="xla")


def test_custom_strategy_with_format_patterns():
    name = "_test_fmt_strategy"
    try:
        @planning.register_strategy(name, cost=lambda p, pl: 0.0,
                                    formats=("w4a8_*",))
        def _run(x2, qt, plan, *, interpret=None):
            return w4a8_matmul_ref(x2, qt)

        assert name in strategies_for_format("w4a8_g128")
        assert name not in strategies_for_format("w4a16_g128")
        # irresistible cost: the planner picks it for w4a8 problems only
        prob = MatmulProblem(M=4, N=64, K=256, format="w4a8_g128")
        assert plan_matmul(prob, use_cache=False).strategy == name
        prob16 = MatmulProblem(M=4, N=64, K=256, format="w4a16_g128")
        assert plan_matmul(prob16, use_cache=False).strategy != name
    finally:
        planning._REGISTRY.pop(name, None)


# ---------------------------------------------------------------------------
# quantize_tree with a format / end-to-end layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt_name,max_rel", [("w8a16_channel", 0.02),
                                              ("w4a8_g128", 0.25)])
def test_quantize_tree_with_format(fmt_name, max_rel):
    params = {"proj": {"kernel": _w(256, 64)},
              "stack": {"kernel": jnp.stack([_w(256, 64, s) for s in (1, 2)])}}
    from repro.models import layers
    qp = layers.quantize_tree(params, format=fmt_name, group_size=128,
                              min_size=0)
    for leaf in jax.tree_util.tree_leaves(
            qp, is_leaf=lambda t: isinstance(t, QuantizedTensor)):
        assert isinstance(leaf, QuantizedTensor)
        assert leaf.format.name == fmt_name
    # the quantized linear still runs through the planned path
    x = _x()
    y = layers.linear(qp["proj"], x)
    want = np.asarray(x) @ np.asarray(params["proj"]["kernel"])
    rel = np.abs(np.asarray(y, np.float32) - want).mean() / np.abs(want).mean()
    assert y.shape == (4, 64) and rel < max_rel, rel


def test_quantize_tree_adaptive_group_keeps_format_family():
    from repro.models import layers
    params = {"odd": {"kernel": _w(192, 64)}}       # 192 % 128 != 0, % 64 == 0
    qp = layers.quantize_tree(params, format="w4a8_g128", min_size=0)
    assert qp["odd"]["kernel"].format.name == "w4a8_g64"
    assert qp["odd"]["kernel"].format.quantized_activations


# ---------------------------------------------------------------------------
# checkpoint format sidecars
# ---------------------------------------------------------------------------

def test_checkpoint_round_trips_formats(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    tree = {"a": quantize(_w(), "w8a16_channel"),
            "b": quantize(_w(seed=3), "w4a8_g128", symmetric=False),
            "dense": jnp.ones((4,), jnp.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    out, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 1
    assert out["a"].format.name == "w8a16_channel"
    assert out["b"].format.name == "w4a8_g128_asym"
    np.testing.assert_array_equal(np.asarray(out["a"].packed),
                                  np.asarray(tree["a"].packed))
    np.testing.assert_array_equal(np.asarray(out["b"].zeros),
                                  np.asarray(tree["b"].zeros))


def test_checkpoint_format_mismatch_fails_loudly(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    tree = {"q": quantize(_w(), "w8a16_channel")}
    save_checkpoint(str(tmp_path), 1, tree)
    like = {"q": quantize(_w(), "w4a16_g128")}
    with pytest.raises(ValueError, match="format mismatch") as ei:
        restore_checkpoint(str(tmp_path), like)
    assert "w8a16_channel" in str(ei.value) and "w4a16_g128" in str(ei.value)


def test_checkpoint_quantized_vs_dense_template_mismatch(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    # dense checkpoint into a quantized template
    save_checkpoint(str(tmp_path / "d"), 1, {"q": _w()})
    with pytest.raises(ValueError, match="dense"):
        restore_checkpoint(str(tmp_path / "d"),
                           {"q": quantize(_w(), "w4a16_g128")})
    # quantized checkpoint into a dense template
    save_checkpoint(str(tmp_path / "q"), 1,
                    {"q": quantize(_w(), "w4a16_g128")})
    with pytest.raises(ValueError, match="quantized"):
        restore_checkpoint(str(tmp_path / "q"), {"q": _w()})
