"""Speculative decoding tests: greedy token-identity parity vs the
non-speculative paged engine (danube + internvl2 × {ngram, draft} ×
{chunked prefill on/off}, plus ngram on the recurrent/enc-dec carry
families via verify-step carry checkpoints), allocator-level rollback of
rejected drafts (txn unit tests + end-state property with an
always-wrong proposer), up-front proposer validation, and the TP×DP
subprocess parity case for the forced-8-device CI job."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quant import get_kv_format
from repro.launch.serve import main as serve_main
from repro.models import transformer as T
from repro.runtime import kvcache as kvc
from repro.runtime import speculative as spec
from repro.runtime.engine import Request, ServingEngine

ROOT = os.path.join(os.path.dirname(__file__), "..")
KEY = jax.random.PRNGKey(0)

_PARAMS = {}
_BASELINE = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        p = T.init_params(KEY, cfg)
        _PARAMS[cfg.name] = T.quantize_params(p, cfg, min_size=0)
    return _PARAMS[cfg.name]


def _cfg(arch):
    return dataclasses.replace(configs.get_reduced(arch),
                               w4a16_strategy="xla")


def _requests(cfg, n, P, G):
    """n requests; the first two share a prompt (prefix sharing under
    speculation), with a repeated tail segment so ngram has something to
    match."""
    base = jax.random.randint(KEY, (max(2, P // 3),), 0, cfg.vocab_size)
    rep = jnp.tile(base, -(-P // base.shape[0]))[:P]
    toks = jax.random.randint(jax.random.fold_in(KEY, 9), (n, P), 0,
                              cfg.vocab_size)
    reqs = []
    for i in range(n):
        kw = {}
        if cfg.vision_prefix:
            kw["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, min(i, 1)),
                (cfg.vision_prefix, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            kw["audio_embeds"] = jax.random.normal(
                jax.random.fold_in(KEY, min(i, 1)),
                (cfg.encoder_seq, cfg.d_model), cfg.dtype)
        prompt = rep if i < 2 else toks[i]
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=G,
                            arrival_step=i, **kw))
    return reqs


def _run(arch, *, prefill_chunk, speculate=None, spec_k=3,
         n=3, P=8, G=6, B=2):
    cfg = _cfg(arch)
    eng = ServingEngine(cfg, _params(cfg), max_batch=B, max_prompt_len=P,
                        max_new_tokens=G, page_size=8,
                        prefill_chunk=prefill_chunk, speculate=speculate,
                        spec_k=spec_k)
    rep = eng.run(_requests(cfg, n, P, G))
    return rep, eng


def _baseline(arch, prefill_chunk):
    key = (arch, prefill_chunk)
    if key not in _BASELINE:
        _BASELINE[key] = _run(arch, prefill_chunk=prefill_chunk)[0].results
    return _BASELINE[key]


# ---------------------------------------------------------------------------
# greedy token-identity parity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "internvl2-1b"])
@pytest.mark.parametrize("proposer", ["ngram", "draft:layers=1"])
@pytest.mark.parametrize("prefill_chunk", [None, 4])
def test_speculative_parity(arch, proposer, prefill_chunk):
    """Speculative greedy decode emits EXACTLY the non-speculative paged
    engine's tokens — for an n-gram self-proposer and a 1-layer random
    draft model, whole-prompt and chunked prefill, continuous batching
    with staggered arrivals and slot reuse. danube additionally exercises
    the SWA wrap clamp (cache_len 16 < prompt+gen positions)."""
    rep, eng = _run(arch, prefill_chunk=prefill_chunk, speculate=proposer)
    assert rep.results == _baseline(arch, prefill_chunk)
    assert rep.accepted_tokens <= rep.proposed_tokens
    assert rep.decode_tokens == sum(len(v) for v in rep.results.values()) \
        - len(rep.results)       # first tokens come from prefill
    # every page returned: rollback + evict left no leaked references
    assert eng.alloc.pages_in_use == 0
    assert eng.alloc.pages_free == eng.num_pages - 1


def test_oracle_draft_accepts_everything():
    """A draft identical to the target proposes the target's own greedy
    continuation — acceptance must be 100% and the run must finish in
    fewer decode steps than token-by-token decode (non-SWA arch, so the
    wrap clamp never truncates proposals)."""
    cfg = _cfg("starcoder2-7b")
    params = _params(cfg)
    oracle = spec.DraftModelProposer(cfg, params)
    base, _ = _run("starcoder2-7b", prefill_chunk=4)
    eng = ServingEngine(cfg, params, max_batch=2, max_prompt_len=8,
                        max_new_tokens=6, page_size=8, prefill_chunk=4,
                        speculate=oracle, spec_k=3)
    rep = eng.run(_requests(cfg, 3, 8, 6))
    assert rep.results == base.results
    assert rep.proposed_tokens > 0
    assert rep.accepted_tokens == rep.proposed_tokens
    assert rep.acceptance_rate == 1.0
    assert rep.steps < base.steps


# ---------------------------------------------------------------------------
# allocator-level rollback
# ---------------------------------------------------------------------------

def _snapshot(alloc):
    return (alloc.pages_in_use, alloc.pages_free, dict(alloc._ref),
            dict(alloc._index), dict(alloc._key_of))


def test_rollback_restores_allocator_exactly():
    """A rejected draft tail crossing a page boundary out of a SHARED
    prefix page (CoW + fresh alloc in one txn) rolls back to the exact
    pre-step allocator state: refcounts, prefix index, free pool, block
    table — and the shared block is re-adopted, never re-published."""
    cfg = _cfg("starcoder2-7b")
    eng = ServingEngine(cfg, _params(cfg), max_batch=2, max_prompt_len=16,
                        max_new_tokens=16, page_size=8)
    ps = eng.page_size
    eng._tables = np.full((2, eng.pages_slot), -1, np.int32)
    state = eng._init_state()
    # slot 0 owns a published prompt page; slot 1 adopts it (shared)
    shared = eng.alloc.alloc()
    eng.alloc.publish("prefix-key", shared)
    eng._tables[0][0] = shared
    assert eng.alloc.lookup("prefix-key") == shared
    eng._tables[1][0] = shared
    assert eng.alloc.refcount(shared) == 2
    before = _snapshot(eng.alloc)
    tbl_before = eng._tables[1].copy()

    # slot 1's draft tail covers offsets ps-1 .. ps+1: page 0 (shared →
    # CoW) and page 1 (unmapped → alloc)
    txn = []
    state, _ = eng._ensure_pages(state, 1, [ps - 1, ps, ps + 1], txn=txn)
    assert [op[0] for op in txn] == ["cow", "alloc"]
    copy_bid = int(eng._tables[1][0])
    assert copy_bid != shared and eng.alloc.refcount(shared) == 1
    assert int(eng._tables[1][1]) >= 0

    # every draft rejected: last accepted position stayed in page -1's
    # territory → both mappings unwind
    state, dirty = eng._rollback_pages(state, 1, txn, -1)
    assert dirty
    assert _snapshot(eng.alloc) == before
    assert (eng._tables[1] == tbl_before).all()
    assert int(eng._tables[1][0]) == shared       # re-adopted, ref back to 2
    # the freed copy's tags were wiped (no stale entries for its next owner)
    pool = state["cache"]["kv"]
    assert int(pool.page_pos[:, copy_bid].max()) == -1


def test_rollback_partial_keep():
    """Accepted positions reaching into the CoW'd page keep the copy;
    only the overhang page beyond the accepted frontier unwinds."""
    cfg = _cfg("starcoder2-7b")
    eng = ServingEngine(cfg, _params(cfg), max_batch=2, max_prompt_len=16,
                        max_new_tokens=16, page_size=8)
    eng._tables = np.full((2, eng.pages_slot), -1, np.int32)
    state = eng._init_state()
    shared = eng.alloc.alloc()
    eng.alloc.publish("k", shared)
    eng._tables[0][0] = shared
    eng.alloc.lookup("k")
    eng._tables[1][0] = shared
    txn = []
    state, _ = eng._ensure_pages(state, 1, [7, 8], txn=txn)
    copy_bid = int(eng._tables[1][0])
    overhang = int(eng._tables[1][1])
    state, _ = eng._rollback_pages(state, 1, txn, 0)     # frontier in page 0
    assert int(eng._tables[1][0]) == copy_bid            # CoW kept
    assert int(eng._tables[1][1]) == -1                  # alloc unwound
    assert eng.alloc.refcount(overhang) == 0
    assert eng.alloc.refcount(copy_bid) == 1
    assert eng.alloc.peek("k") == shared


class _AlwaysWrong(spec.Proposer):
    """Proposes syntactically valid but (near-certainly) rejected drafts:
    the maximum-vocab token is a measure-zero greedy choice for random
    fp32 logits, so every step exercises full rollback."""

    name = "ngram"          # piggybacks the registry checks

    def __init__(self, vocab):
        self.vocab = vocab

    def propose(self, views, k):
        return {v.slot: [self.vocab - 1] * k for v in views}


def test_rejected_drafts_leave_no_residue():
    """End-state property: a proposer whose drafts always miss leaves the
    engine's results token-identical and the allocator EXACTLY empty —
    shared-prefix slots included, with draft tails crossing page
    boundaries every few steps (page_size 8, gen 12)."""
    cfg = _cfg("starcoder2-7b")
    base, _ = _run("starcoder2-7b", prefill_chunk=4, G=12)
    rep, eng = _run("starcoder2-7b", prefill_chunk=4, G=12,
                    speculate=_AlwaysWrong(cfg.vocab_size), spec_k=3)
    assert rep.results == base.results
    assert rep.proposed_tokens > 0 and rep.accepted_tokens == 0
    assert eng.alloc.pages_in_use == 0
    assert eng.alloc.pages_free == eng.num_pages - 1
    assert eng.alloc._index == {} and eng.alloc._ref == {}
    # null block aside, every pool tag was wiped on the way out
    pool = eng.last_state["cache"]["kv"]
    assert int(pool.page_pos.max()) == -1


@pytest.mark.parametrize("arch", ["rwkv6-7b", "hymba-1.5b",
                                  "whisper-small"])
def test_speculative_parity_carry_families(arch):
    """Recurrent / enc-dec families speculate now: verify checkpoints the
    wkv/ssm/conv carries per drafted position and the engine rewinds each
    slot to its last accepted checkpoint. Ngram must stay token-identical
    to plain chunked decode; a reject-everything proposer must too — every
    one of its verify steps rewinds the carries to the pre-draft
    checkpoint (accepted = 0), the hardest rewind case."""
    cfg = _cfg(arch)
    rep, _ = _run(arch, prefill_chunk=4, speculate="ngram")
    assert rep.results == _baseline(arch, 4)
    assert rep.accepted_tokens <= rep.proposed_tokens
    wrong, _ = _run(arch, prefill_chunk=4,
                    speculate=_AlwaysWrong(cfg.vocab_size), spec_k=3)
    assert wrong.results == _baseline(arch, 4)
    assert wrong.proposed_tokens > 0 and wrong.accepted_tokens == 0


def test_scatter_chunks_matches_per_slot_scatter():
    """The batched verify-write path lands byte-identical K/V to B
    sequential scatter_chunk calls."""
    fmt = get_kv_format("kv_fp16")
    nb, ps, H, D, B, C = 6, 4, 2, 4, 2, 3
    pool = kvc.init_pool(nb, ps, H, D, jnp.float32, "kv_fp16")
    tables = jnp.asarray([[1, 2], [3, -1]], jnp.int32)
    k = jax.random.normal(KEY, (B, C, H, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (B, C, H, D))
    positions = jnp.asarray([[2, 3, 4], [6, 7, -1]], jnp.int32)
    got = kvc.scatter_chunks(pool, tables, k, v, positions,
                             cache_len=8, fmt=fmt)
    want = pool
    for b in range(B):
        want = kvc.scatter_chunk(want, tables[b], k[b], v[b], positions[b],
                                 cache_len=8, fmt=fmt)
    for l_got, l_want in zip(got, want):
        if l_got is not None:
            np.testing.assert_array_equal(np.asarray(l_got)[1:],
                                          np.asarray(l_want)[1:])


# ---------------------------------------------------------------------------
# up-front validation (CLI refusal path)
# ---------------------------------------------------------------------------

def test_validate_speculate_refusals():
    dense = configs.get_reduced("starcoder2-7b")
    with pytest.raises(ValueError, match="Registered proposers"):
        spec.validate_speculate("bogus", 4, cfg=dense)
    with pytest.raises(ValueError, match="spec-k"):
        spec.validate_speculate("ngram", 0, cfg=dense)
    with pytest.raises(ValueError, match="paged"):
        spec.validate_speculate("ngram", 4, cfg=dense, paged=False)
    swa = configs.get_reduced("h2o-danube-1.8b")        # window=16
    with pytest.raises(ValueError, match="sliding window"):
        spec.validate_speculate("ngram", 16, cfg=swa)
    # recurrent/enc-dec families validate: verify checkpoints their
    # carries through the chunked path, so speculation is no longer a
    # dense-family privilege
    for arch in ("whisper-small", "rwkv6-7b", "hymba-1.5b"):
        assert spec.validate_speculate(
            "ngram", 4, cfg=configs.get_reduced(arch)) == "ngram"
    assert spec.validate_speculate("draft:layers=2", 4, cfg=dense) == "draft"
    assert spec.validate_speculate(None, 4, cfg=dense) is None
    assert spec.validate_speculate("off", 4, cfg=dense) is None


def test_draft_proposer_refuses_carry_family_draft():
    """The DRAFT side still refuses carry families: the draft decodes
    token by token with no checkpoint to rewind a rejected run, unlike
    the target's verify-step carry checkpoints."""
    with pytest.raises(ValueError, match="rewind"):
        spec.DraftModelProposer(configs.get_reduced("rwkv6-7b"))


def test_serve_cli_refuses_bad_speculate():
    argv = ["--arch", "starcoder2-7b", "--reduced", "--batch", "2",
            "--prompt-len", "8", "--gen", "3", "--strategy", "xla"]
    with pytest.raises(ValueError, match="Registered proposers"):
        serve_main(argv + ["--speculate", "nope"])
    with pytest.raises(ValueError, match="spec-k"):
        serve_main(argv + ["--speculate", "ngram", "--spec-k", "0"])


def test_serve_cli_speculative_preset():
    """starcoder2's preset turns ngram speculation on; the CLI run must
    produce the full requested generation through the verify path."""
    gen = serve_main([
        "--arch", "starcoder2-7b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "4", "--strategy", "xla",
    ])
    assert gen.shape == (2, 4)
    assert int(gen.min()) >= 0


# ---------------------------------------------------------------------------
# multi-device parity (subprocess with 8 fake CPU devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro import configs
from repro.kernels import planning
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.runtime.engine import Request, ServingEngine

out = {}
P, G, R, SLOTS, K = 8, 6, 3, 2, 3


def build_requests(cfg, key):
    base = jax.random.randint(key, (4,), 0, cfg.vocab_size)
    rep = jnp.tile(base, -(-P // 4))[:P]
    toks = jax.random.randint(jax.random.fold_in(key, 9), (R, P), 0,
                              cfg.vocab_size)
    return [Request(rid=i, prompt=(rep if i < 2 else toks[i]),
                    max_new_tokens=G, arrival_step=i) for i in range(R)]


def run_engine(cfg, params, mesh, reqs, speculate):
    eng = ServingEngine(cfg, params, mesh=mesh, max_batch=SLOTS,
                        max_prompt_len=P, max_new_tokens=G,
                        prefill_chunk=4, speculate=speculate, spec_k=K)
    rep = eng.run(reqs)
    return {str(k): v for k, v in sorted(rep.results.items())}, rep


cfg = configs.get_reduced("h2o-danube-1.8b")     # w4a16_strategy="auto"
key = jax.random.PRNGKey(0)
params = T.quantize_params(T.init_params(key, cfg), cfg, min_size=0)
reqs = build_requests(cfg, key)
planning.PLAN_CACHE.clear()
single, _ = run_engine(cfg, params, None, reqs, None)
for dp, tp in [(2, 2), (1, 4)]:
    planning.PLAN_CACHE.clear()
    mesh = make_local_mesh(data=dp, model=tp)
    sharded, rep = run_engine(cfg, params, mesh, reqs, "ngram")
    tag = f"{dp}x{tp}"
    out[tag + "/match"] = sharded == single
    out[tag + "/counters"] = rep.accepted_tokens <= rep.proposed_tokens
    # verify GEMMs are M = B*(k+1) problems; shard-local planning costs
    # them at the per-rank shape (data axis divides the rows), not the
    # M=B decode shape
    keys = list(planning.PLAN_CACHE._plans)
    out[tag + "/plan_M_verify"] = any(
        p.M == (SLOTS // dp) * (K + 1) for p in keys)
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_speculative_parity():
    """TP×DP speculative engine decode (ngram, chunked prefill, staggered
    arrivals) is token-identical to single-device NON-speculative decode,
    with verify-shaped (M = B*(k+1)) kernel plans."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out and all(out.values()), {k: v for k, v in out.items() if not v}
