"""Plan-based API tests: registry dispatch parity, plan serialization,
plan-cache hit/miss + JSON persistence, the w4a16_matmul compatibility
shim, and the planner's strategy choice / Split-K edge cases."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.kernels import ops, planning, ref
from repro.kernels.planning import (
    PLAN_CACHE, KernelPlan, MatmulProblem, PlanCache, choose_split_k,
    execute, plan_matmul, register_strategy, resolve_plan,
)

KEY = jax.random.PRNGKey(0)


def _operands(M=8, K=512, N=256, g=128):
    k1, k2 = jax.random.split(KEY)
    w = jax.random.normal(k1, (K, N), jnp.float32)
    x = jax.random.normal(k2, (M, K), jnp.float32)
    return x, quantize(w, group_size=g)


# ---------------------------------------------------------------------------
# problem / plan objects
# ---------------------------------------------------------------------------

def test_problem_hashable_and_from_operands():
    x, qt = _operands()
    p1 = MatmulProblem.from_operands(x, qt)
    p2 = MatmulProblem.from_operands(x, qt)
    assert p1 == p2 and hash(p1) == hash(p2)
    assert (p1.M, p1.N, p1.K) == (8, 256, 512)
    assert p1.group_size == 128 and not p1.has_zeros
    # leading dims collapse into M
    p3 = MatmulProblem.from_operands(x.reshape(2, 4, 512), qt)
    assert p3 == p1
    assert MatmulProblem.from_dict(p1.to_dict()) == p1


def test_kernel_plan_json_round_trip():
    plan = KernelPlan(strategy="fused", split_k=4, block_m=64, block_n=128,
                      block_k=256, out_dtype="bfloat16")
    assert KernelPlan.from_json(plan.to_json()) == plan
    # defaulted fields survive too
    assert KernelPlan.from_json(KernelPlan(strategy="xla").to_json()) \
        == KernelPlan(strategy="xla")
    # the JSON is plain data (editable / diffable)
    blob = json.loads(plan.to_json())
    assert blob["strategy"] == "fused" and blob["split_k"] == 4


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registered_strategies_agree_with_oracle():
    """Every strategy supporting the tensor's format matches ref.w4a16_ref
    within tolerance (format-incompatible ones are refused — see
    tests/test_formats.py)."""
    x, qt = _operands()
    want = np.asarray(ref.w4a16_ref(x, qt))
    names = planning.strategies_for_format(qt.format.name)
    assert set(names) >= {"fused", "decoupled", "xla", "reference"}
    for name in names:
        plan = plan_matmul(MatmulProblem.from_operands(x, qt), strategy=name)
        got = execute(plan, x, qt, interpret=True)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-4, atol=1e-3, err_msg=name)


def test_decoupled_is_registry_routed():
    """The paper pipeline is reachable via the registry alone — the
    "new strategy needs no dispatcher edits" acceptance check."""
    strat = planning.get_strategy("decoupled")
    x, qt = _operands()
    got = strat.execute(x, qt, KernelPlan(strategy="decoupled", split_k=2),
                        interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.w4a16_ref(x, qt)),
                               rtol=1e-4, atol=1e-3)


def test_register_strategy_plugs_into_planner():
    """A decorator-registered strategy is immediately planable/executable,
    and an irresistible cost model makes the planner pick it."""
    name = "_test_registered"
    try:
        @register_strategy(name, cost=lambda problem, plan: 0.0)
        def _run(x2, qt, plan, *, interpret=None):
            return ref.w4a16_ref(x2, qt)

        x, qt = _operands()
        problem = MatmulProblem.from_operands(x, qt)
        plan = plan_matmul(problem, use_cache=False)
        assert plan.strategy == name
        np.testing.assert_allclose(
            np.asarray(execute(plan, x, qt)),
            np.asarray(ref.w4a16_ref(x, qt)), rtol=1e-5, atol=1e-5)
    finally:
        planning._REGISTRY.pop(name, None)


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown strategy"):
        planning.get_strategy("no-such-kernel")


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_planner_prefers_xla_off_tpu_and_fused_on_tpu():
    base = dict(M=4, N=1024, K=4096, group_size=128,
                act_dtype="bfloat16", out_dtype="bfloat16")
    assert plan_matmul(MatmulProblem(backend="cpu", **base),
                       use_cache=False).strategy == "xla"
    assert plan_matmul(MatmulProblem(backend="tpu", **base),
                       use_cache=False).strategy == "fused"


def test_planner_falls_back_on_unsupported_shapes():
    """K not divisible by the group size: Pallas strategies are ineligible
    but the planner still returns a runnable plan."""
    problem = MatmulProblem(M=4, N=128, K=300, group_size=128, backend="tpu")
    plan = plan_matmul(problem, use_cache=False)
    assert plan.strategy in ("xla", "reference")
    # group-divisible odd K (hymba-style) stays Pallas-eligible
    ok = MatmulProblem(M=4, N=128, K=320, group_size=32, backend="tpu")
    assert plan_matmul(ok, use_cache=False).strategy == "fused"


def test_planner_refine_uses_tile_search():
    from repro.kernels.autotune import autotune_w4a16

    problem = MatmulProblem(M=8, N=1024, K=4096, backend="tpu")
    plan = plan_matmul(problem, strategy="fused", refine=True)
    bm, bn, bk, s = autotune_w4a16(8, 1024, 4096, group=128)
    assert (plan.block_m, plan.block_n, plan.block_k, plan.split_k) \
        == (bm, bn, bk, s)


def test_choose_split_k_decode_regime_and_non_divisible_k():
    assert choose_split_k(1, 128, 16384) > 1            # decode regime
    assert choose_split_k(2048, 8192, 4096) == 1        # plenty of tiles
    # regression: K not divisible by group_size must not split (and must
    # not raise) — the old heuristic assumed divisibility
    assert choose_split_k(1, 128, 16384 + 64, group_size=128) == 1
    assert choose_split_k(1, 128, 100, group_size=128) == 1


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_hit_miss_and_persistence(tmp_path):
    cache = PlanCache()
    x, qt = _operands()
    problem = MatmulProblem.from_operands(x, qt)

    p1 = plan_matmul(problem, cache=cache)
    assert (cache.hits, cache.misses, len(cache)) == (0, 1, 1)
    p2 = plan_matmul(problem, cache=cache)
    assert p2 == p1
    assert (cache.hits, cache.misses) == (1, 1)         # second call hits

    path = tmp_path / "plans.json"
    assert cache.save(str(path)) == 1
    fresh = PlanCache()
    assert fresh.load(str(path)) == 1
    assert fresh.get(problem) == p1                      # survives the disk trip
    assert fresh.hits == 1


def test_plan_cache_save_is_atomic(tmp_path, monkeypatch):
    """A crash mid-save must never truncate the shared plan-cache JSON:
    the write goes to a tmp file and lands via os.replace."""
    path = tmp_path / "plans.json"
    cache = PlanCache()
    cache.put(MatmulProblem(M=1, N=128, K=256), KernelPlan(strategy="xla"))
    cache.save(str(path))
    before = path.read_text()
    assert PlanCache().load(str(path)) == 1

    # serialization blowing up leaves the previous file byte-identical
    cache.put(MatmulProblem(M=2, N=128, K=256), KernelPlan(strategy="xla"))
    monkeypatch.setattr(planning.json, "dumps",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("disk")))
    with pytest.raises(OSError):
        cache.save(str(path))
    monkeypatch.undo()
    assert path.read_text() == before
    # no tmp litter either way
    assert [p.name for p in tmp_path.iterdir()] == ["plans.json"]
    # and a clean save overwrites atomically with the new contents
    assert cache.save(str(path)) == 2
    assert PlanCache().load(str(path)) == 2


def test_refine_bypasses_stale_cache_hit():
    """refine=True must reach the tile search even when a heuristic plan is
    already cached (and the refined plan replaces it)."""
    from repro.kernels.autotune import autotune_w4a16

    cache = PlanCache()
    problem = MatmulProblem(M=8, N=1024, K=4096, backend="tpu")
    heuristic = plan_matmul(problem, cache=cache)
    refined = plan_matmul(problem, refine=True, cache=cache)
    bm, bn, bk, s = autotune_w4a16(8, 1024, 4096, group=128)
    assert (refined.block_m, refined.block_n, refined.block_k) == (bm, bn, bk)
    assert cache.get(problem) == refined            # overwrote the heuristic
    assert heuristic.strategy == refined.strategy == "fused"


def test_tolerant_load_survives_corrupt_and_missing_files(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"version": 1, "plans": [{"nope"')
    with pytest.raises(ValueError):
        PlanCache().load(str(bad))
    assert planning.load_plan_cache(str(bad), tolerant=True) == -1
    assert planning.load_plan_cache(str(tmp_path / "gone.json"),
                                    tolerant=True) == -1
    # structurally-wrong-but-valid JSON raises ValueError, not TypeError
    for blob in ("[]", '{"version": 1, "plans": [{"problem": {"bogus": 1},'
                 ' "plan": {"strategy": "xla"}}]}'):
        bad.write_text(blob)
        with pytest.raises(ValueError):
            PlanCache().load(str(bad))


def test_load_drops_plans_for_unregistered_strategies(tmp_path):
    """A cache written by a build with extra strategies must not smuggle
    un-executable plans past loading (they'd crash at execute time)."""
    path = tmp_path / "future.json"
    cache = PlanCache()
    problem = MatmulProblem(M=1, N=128, K=256)
    cache.put(problem, KernelPlan(strategy="xla"))
    cache.put(dataclasses.replace(problem, M=2),
              KernelPlan(strategy="w4a8_from_the_future"))
    cache.save(str(path))
    fresh = PlanCache()
    assert fresh.load(str(path)) == 1                   # unknown one dropped
    assert fresh.get(problem) == KernelPlan(strategy="xla")


def test_plan_cache_distinguishes_problems():
    cache = PlanCache()
    a = MatmulProblem(M=1, N=1024, K=4096, backend="tpu")
    b = dataclasses.replace(a, M=512)
    plan_matmul(a, cache=cache)
    plan_matmul(b, cache=cache)
    assert len(cache) == 2 and cache.hits == 0


def test_plan_for_params_warm_starts_layer_lookups():
    """Pre-planned entries must be keyed exactly like the layer-time lookup
    (2-D scan slices, batch=1) — regression for the write-only warm-start."""
    from repro.core.quant import QuantizedTensor
    from repro.models import layers as L

    params = {"kernel": jax.random.normal(KEY, (3, 256, 128), jnp.float32)}
    qparams = L.quantize_tree(params, group_size=64, min_size=0)
    plans = planning.plan_for_params(qparams, M=4)
    assert set(plans) == {"256x128"}

    qt3 = qparams["kernel"]
    qt0 = QuantizedTensor(qt3.packed[0], qt3.scales[0], None,
                          qt3.group_size, qt3.out_dtype)   # one scan slice
    x = jnp.zeros((4, 256), jnp.float32)
    hits0 = PLAN_CACHE.hits
    got = plan_matmul(MatmulProblem.from_operands(x, qt0))
    assert PLAN_CACHE.hits == hits0 + 1                    # warm-start hit
    assert got == plans["256x128"]


def test_module_level_cache_round_trip(tmp_path):
    x, qt = _operands(M=3, K=256, N=128, g=64)
    problem = MatmulProblem.from_operands(x, qt)
    plan = plan_matmul(problem)                          # populates PLAN_CACHE
    path = tmp_path / "global.json"
    assert planning.save_plan_cache(str(path)) >= 1
    PLAN_CACHE._plans.pop(problem)
    assert planning.load_plan_cache(str(path)) >= 1
    assert PLAN_CACHE.get(problem) == plan


# ---------------------------------------------------------------------------
# config override resolution
# ---------------------------------------------------------------------------

def test_resolve_plan_honors_config_overrides():
    x, qt = _operands()
    problem = MatmulProblem.from_operands(x, qt)

    class Cfg:
        w4a16_strategy = "auto"
        w4a16_plan = None

    cfg = Cfg()
    assert resolve_plan(problem, cfg) == plan_matmul(problem)

    cfg.w4a16_strategy = "decoupled"
    assert resolve_plan(problem, cfg).strategy == "decoupled"

    pinned = KernelPlan(strategy="reference")
    cfg.w4a16_plan = pinned
    assert resolve_plan(problem, cfg) is pinned

    cfg.w4a16_plan = {problem.layer_key: {"strategy": "xla", "split_k": 1}}
    assert resolve_plan(problem, cfg).strategy == "xla"

    cfg.w4a16_plan = {"9999x9999": pinned}              # wrong layer: fall back
    assert resolve_plan(problem, cfg).strategy == "decoupled"

    cfg.w4a16_plan = KernelPlan(strategy="fused", split_k=2).to_json()
    assert resolve_plan(problem, cfg) == KernelPlan(strategy="fused",
                                                    split_k=2)


# ---------------------------------------------------------------------------
# compatibility shim
# ---------------------------------------------------------------------------

def test_w4a16_matmul_shim_matches_primary_path():
    x, qt = _operands()
    want = np.asarray(ref.w4a16_ref(x, qt))
    # "auto" == plan+execute
    got = ops.w4a16_matmul(x, qt)
    prim = execute(plan_matmul(MatmulProblem.from_operands(x, qt)), x, qt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(prim))
    # named strategies and kwargs still work unchanged
    for s in ("fused", "decoupled", "xla", "reference"):
        o = ops.w4a16_matmul(x, qt, strategy=s, interpret=True)
        np.testing.assert_allclose(np.asarray(o), want,
                                   rtol=1e-4, atol=1e-3, err_msg=s)
    o = ops.w4a16_matmul(x, qt, strategy="fused", split_k=2, interpret=True)
    np.testing.assert_allclose(np.asarray(o), want, rtol=1e-4, atol=1e-3)
    with pytest.raises(ValueError, match="unknown strategy"):
        ops.w4a16_matmul(x, qt, strategy="bogus")


def test_shim_leading_dims_and_out_dtype():
    x, qt = _operands()
    y = ops.w4a16_matmul(x.reshape(2, 4, 512), qt, out_dtype=jnp.bfloat16)
    assert y.shape == (2, 4, 256) and y.dtype == jnp.bfloat16
