"""Per-kernel shape/dtype sweeps: every Pallas kernel vs its ref.py oracle
(interpret=True on CPU; the kernels target TPU BlockSpec tiling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize
from repro.kernels import ops, ref
from repro.kernels.gemm import gemm
from repro.kernels.w4a16_decoupled import (
    dequant_w4, reduce_partials, splitk_gemm, w4a16_decoupled,
)
from repro.kernels.w4a16_fused import w4a16_fused

KEY = jax.random.PRNGKey(0)


def tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


def rel_close(got, want, dt):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = max(np.abs(want).max(), 1.0)
    np.testing.assert_allclose(got / scale, want / scale, **tol(dt))


SWEEP = [
    # M, K, N, group, symmetric, dtype
    (8, 256, 128, 128, True, jnp.float32),
    (1, 512, 128, 64, True, jnp.bfloat16),      # decode-like: M=1, K>N
    (16, 1024, 256, 128, False, jnp.float32),   # asymmetric (zero-points)
    (33, 384, 256, 128, True, jnp.float32),     # M not sublane-aligned
    (4, 512, 384, 256, True, jnp.bfloat16),     # group > default block
    (2, 320, 128, 32, True, jnp.float32),       # odd K (hymba-style)
]


@pytest.mark.parametrize("M,K,N,g,sym,dt", SWEEP)
def test_w4a16_fused_vs_oracle(M, K, N, g, sym, dt):
    k1, k2 = jax.random.split(KEY)
    w = jax.random.normal(k1, (K, N), jnp.float32)
    x = jax.random.normal(k2, (M, K), jnp.float32).astype(dt)
    qt = quantize(w, group_size=g, symmetric=sym, out_dtype=dt)
    want = ref.w4a16_ref(x, qt)
    got = w4a16_fused(x, qt, interpret=True)
    rel_close(got, want, dt)


@pytest.mark.parametrize("M,K,N,g,sym,dt", SWEEP)
def test_w4a16_decoupled_vs_oracle(M, K, N, g, sym, dt):
    k1, k2 = jax.random.split(KEY)
    w = jax.random.normal(k1, (K, N), jnp.float32)
    x = jax.random.normal(k2, (M, K), jnp.float32).astype(dt)
    qt = quantize(w, group_size=g, symmetric=sym, out_dtype=dt)
    want = ref.w4a16_ref(x, qt)
    sk = 4 if (K % 4 == 0 and (K // 4) % g == 0) else 1
    got = w4a16_decoupled(x, qt, split_k=sk, interpret=True)
    rel_close(got, want, dt)


@pytest.mark.parametrize("M,K,N,dt", [
    (8, 256, 128, jnp.float32), (1, 512, 256, jnp.bfloat16),
    (64, 1024, 512, jnp.bfloat16), (5, 128, 128, jnp.float32),
])
def test_gemm_vs_oracle(M, K, N, dt):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (M, K), jnp.float32).astype(dt)
    w = jax.random.normal(k2, (K, N), jnp.float32).astype(dt)
    rel_close(gemm(x, w, interpret=True), ref.gemm_ref(x, w), dt)


@pytest.mark.parametrize("K,N,g,sym", [
    (256, 128, 128, True), (512, 256, 64, False), (1024, 128, 256, True),
])
def test_phase1_dequant_kernel(K, N, g, sym):
    w = jax.random.normal(KEY, (K, N), jnp.float32)
    qt = quantize(w, group_size=g, symmetric=sym, out_dtype=jnp.bfloat16)
    want = ref.dequant_ref(qt.packed, qt.scales, qt.zeros, g, jnp.bfloat16)
    got = dequant_w4(qt, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("S", [1, 2, 4, 8])
def test_phase2_splitk_partials(S):
    M, K, N = 8, 1024, 128
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    got = splitk_gemm(x, w, split_k=S, interpret=True)
    want = ref.splitk_partials_ref(x, w, S)
    assert got.shape == (S, M, N) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_phase3_reduce():
    parts = jax.random.normal(KEY, (4, 16, 128), jnp.float32)
    got = reduce_partials(parts, out_dtype=jnp.bfloat16, interpret=True)
    want = ref.reduce_ref(parts, jnp.bfloat16)
    rel_close(got, want, jnp.bfloat16)


@pytest.mark.parametrize("S", [1, 2, 4])
def test_splitk_invariance_fused(S):
    """Paper Alg. 1 invariant: the result is independent of the split factor."""
    M, K, N = 4, 1024, 128
    w = jax.random.normal(KEY, (K, N), jnp.float32)
    x = jax.random.normal(KEY, (M, K), jnp.float32)
    qt = quantize(w, group_size=128)
    base = w4a16_fused(x, qt, split_k=1, interpret=True)
    got = w4a16_fused(x, qt, split_k=S, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-5, atol=1e-4)


def test_strategies_agree():
    """fused ≡ decoupled ≡ xla ≡ reference on the same quantized weight."""
    M, K, N = 8, 512, 256
    w = jax.random.normal(KEY, (K, N), jnp.float32)
    x = jax.random.normal(KEY, (M, K), jnp.float32)
    qt = quantize(w, group_size=128)
    outs = {
        s: ops.w4a16_matmul(x, qt, strategy=s, interpret=True)
        for s in ("fused", "decoupled", "xla", "reference")
    }
    for s, o in outs.items():
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(outs["reference"]),
            rtol=1e-5, atol=1e-4, err_msg=s)


def test_batched_leading_dims():
    """w4a16_matmul contracts the last dim of arbitrary leading shapes."""
    w = jax.random.normal(KEY, (256, 128), jnp.float32)
    x = jax.random.normal(KEY, (2, 3, 256), jnp.float32)
    qt = quantize(w, group_size=64)
    y = ops.w4a16_matmul(x, qt, strategy="fused", interpret=True)
    assert y.shape == (2, 3, 128)
    want = ref.w4a16_ref(x.reshape(-1, 256), qt).reshape(2, 3, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_choose_split_k_heuristic():
    """K≫N with small M (LLM decode) → split; big output tiles → don't."""
    assert ops.choose_split_k(1, 128, 16384) > 1          # decode regime
    assert ops.choose_split_k(4, 256, 8192) > 1
    assert ops.choose_split_k(2048, 8192, 4096) == 1      # plenty of tiles
    assert ops.choose_split_k(1, 128, 128) == 1           # K too shallow
