"""Data pipeline determinism + host sharding."""
import numpy as np

from repro.data import SyntheticTokenStream, make_batch_iterator


def test_deterministic_resume():
    s = SyntheticTokenStream(vocab_size=512, seq_len=16, batch_size=4, seed=7)
    a = s.batch_at(123)
    b = s.batch_at(123)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    it = make_batch_iterator(s, start_step=123)
    c = next(it)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))


def test_hosts_draw_disjoint_streams():
    a = SyntheticTokenStream(512, 16, 4, seed=7, host_id=0, num_hosts=2)
    b = SyntheticTokenStream(512, 16, 4, seed=7, host_id=1, num_hosts=2)
    assert not np.array_equal(np.asarray(a.batch_at(0)["tokens"]),
                              np.asarray(b.batch_at(0)["tokens"]))


def test_labels_are_next_tokens():
    s = SyntheticTokenStream(512, 16, 4, seed=1)
    b = s.batch_at(0)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert int(b["tokens"].max()) < 512 and int(b["tokens"].min()) >= 0
