"""Sharding rules + dry-run HLO parsing units (single device; specs only)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.dryrun import collective_bytes, _loop_multipliers
from repro.models import transformer as T
from repro.runtime import sharding as shd


class FakeMesh:
    """Spec-level mesh stand-in (no devices needed for rule checks)."""
    def __init__(self, sizes):
        self.shape = dict(sizes)
        self.axis_names = tuple(sizes)


def specs_only(params, mesh_sizes, **kw):
    """Run the rule engine but collect raw PartitionSpecs."""
    mesh = FakeMesh(mesh_sizes)
    import repro.runtime.sharding as s

    real = s.NamedSharding
    try:
        s.NamedSharding = lambda m, spec: spec      # capture specs
        return s.param_shardings(params, mesh, **kw)
    finally:
        s.NamedSharding = real


def test_tp_rules_dense():
    cfg = configs.get_config("granite-20b")
    params = T.abstract_params(cfg)
    specs = specs_only(params, {"data": 16, "model": 16}, fsdp=True)
    lay = specs["layers"]
    # column-parallel QKV/up; row-parallel out/down; fsdp on the other dim
    assert lay["attn"]["wq"]["kernel"] == P(None, "data", "model")
    assert lay["attn"]["wo"]["kernel"] == P(None, "model", "data")
    assert lay["mlp"]["w_up"]["kernel"] == P(None, "data", "model")
    assert lay["mlp"]["w_down"]["kernel"] == P(None, "model", "data")
    assert specs["final_norm"]["scale"] == P()
    # embed: vocab over model
    assert specs["embed"]["table"][0] == "model"


def test_tp_rules_respect_divisibility():
    """internvl2: 14 heads / odd dims — undivisible dims stay replicated."""
    cfg = configs.get_config("internvl2-1b")
    params = T.abstract_params(cfg)
    specs = specs_only(params, {"data": 16, "model": 16}, fsdp=False)
    wq = specs["layers"]["attn"]["wq"]["kernel"]
    # q_dim = 14*64 = 896, 896 % 16 == 0 → sharded; d_model 896 ✓
    assert wq == P(None, None, "model")
    # d_ff 4864 = 38*128; 4864 % 16 == 0 → sharded
    assert specs["layers"]["mlp"]["w_up"]["kernel"][-1] == "model"


def test_quantized_leaves_shard_like_dense():
    cfg = configs.get_reduced("granite-20b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    from repro.models import layers as L
    qparams = L.quantize_tree(params, group_size=32, min_size=0)
    specs = specs_only(qparams, {"data": 2, "model": 2}, fsdp=False)
    qt_spec = specs["layers"]["mlp"]["w_up"]["kernel"]
    # packed (L, K/2, N) and scales (L, K/g, N) both column-parallel on N
    assert qt_spec.packed[-1] == "model"
    assert qt_spec.scales[-1] == "model"


def test_batch_spec_divisibility():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shd.batch_spec(256, m) == P(("pod", "data"))
    assert shd.batch_spec(16, m) == P(("pod",))  # 16 % 32 != 0 → pod only
    assert shd.batch_spec(1, m) == P(None)


def test_batch_axis_entry_normalization():
    """The single helper behind data_shardings AND the step out_shardings:
    singleton tuples normalize to the bare axis name (older jax compares
    P(("data",)) and P("data") unequal, which made prefill/serve
    out_shardings disagree with the input shardings)."""
    m = FakeMesh({"data": 4, "model": 2})
    assert shd.batch_axis_entry(8, m) == "data"          # NOT ("data",)
    assert shd.batch_axis_entry(3, m) is None
    multi = FakeMesh({"pod": 2, "data": 2, "model": 2})
    assert shd.batch_axis_entry(4, multi) == ("pod", "data")
    # the entry data_shardings uses is exactly this helper's output
    assert shd.batch_axis_entry(8, m) == \
        shd._axis_entry(shd.batch_spec(8, m))


def test_collective_parser_counts_loops():
    hlo = """
%cond.1 (arg: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(%x, %c), direction=LT
}
%body.1 (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ag = f32[64]{0} all-gather(%slice), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  ROOT %t = (s32[], f32[8]) tuple(%i, %y)
}
ENTRY %main (p: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  %ar = f32[128]{0} all-reduce(%z), channel_id=2, replica_groups=[16,16]<=[256]T(1,0), to_apply=%sum
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    out = collective_bytes(hlo)
    # all-gather inside 24-trip loop: 64*4 bytes * (15/16) * 24
    assert out["op_counts"]["all-gather"] == 24
    assert out["all-gather"] == (64 * 4 * 15 // 16) * 24
    assert out["op_counts"]["all-reduce"] == 1
    assert out["all-reduce"] == 2 * 128 * 4 * 15 // 16


def test_decode_state_shardings_kv_window():
    cfg = configs.get_config("granite-20b")
    state = jax.eval_shape(lambda: T.init_decode_state(cfg, 128, 32768))
    mesh = FakeMesh({"data": 16, "model": 16})
    import repro.runtime.sharding as s
    real = s.NamedSharding
    try:
        s.NamedSharding = lambda m, spec: spec
        specs = s.decode_state_shardings(state, cfg, mesh)
    finally:
        s.NamedSharding = real
    kspec = specs["cache"]["kv"].k
    # (L, B, W, Hkv, D): batch over data, 32k window over model (kv=1 heads
    # can't shard) — sequence-parallel decode attention
    assert kspec == P(None, "data", "model", None, None)


def test_trip_count_prefers_compare_bound():
    from repro.launch.dryrun import _trip_count
    cond = """
  %c1 = s32[] constant(24)
  %c2 = s32[] constant(151936)
  %i = s32[] get-tuple-element(%arg), index=0
  %lt = pred[] compare(%i, %c1), direction=LT
"""
    assert _trip_count(cond) == 24


def test_trip_count_fused_compare_falls_back_to_min_const():
    from repro.launch.dryrun import _trip_count
    cond = """
  %c1 = s32[] constant(8)
  %cmp = pred[] fusion(%gte, %c1), kind=kLoop, calls=%wrapped_compare
"""
    assert _trip_count(cond) == 8


def test_hlo_costs_counts_scanned_dots():
    import jax, jax.numpy as jnp
    from repro.launch.dryrun import hlo_costs

    def body(h, w):
        return h @ w, None

    h = jnp.zeros((64, 64), jnp.float32)
    ws = jnp.zeros((5, 64, 64), jnp.float32)
    c = jax.jit(lambda h, ws: jax.lax.scan(body, h, ws)[0]).lower(h, ws)
    costs = hlo_costs(c.compile().as_text())
    want = 2 * 64 * 64 * 64 * 5
    assert abs(costs["flops"] - want) / want < 0.01
