"""Multi-device SPMD tests (subprocess with 8 fake CPU devices).

Verifies the sharded train step is numerically equivalent to single-device
execution, and that the sharded W4A16 matmul (shard_map + fused Pallas
kernel) matches the oracle — the TP-composability claim of DESIGN.md.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.compat import set_mesh, shard_map
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import steps as rsteps
from repro.runtime import sharding as shd

out = {}

# ---- sharded vs single-device train step equivalence --------------------
cfg = configs.get_reduced("h2o-danube-1.8b")
key = jax.random.PRNGKey(0)
params = T.init_params(key, cfg)
opt_cfg = AdamWConfig(lr=1e-3)
opt = adamw_init(params, opt_cfg)
settings = rsteps.TrainSettings(microbatches=2, fsdp=True)
toks = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
inputs = {"batch": {"tokens": toks, "labels": toks},
          "step": jnp.zeros((), jnp.int32)}

single = jax.jit(rsteps.make_train_step(cfg, opt_cfg, settings))
p1, o1, m1 = single(params, opt, inputs)

mesh = jax.make_mesh((4, 2), ("data", "model"))
with set_mesh(mesh):
    fn = rsteps.jit_train_step(
        cfg, mesh, settings,
        jax.eval_shape(lambda: params),
        jax.eval_shape(lambda: inputs), opt_cfg)
    p2, o2, m2 = fn(params, opt, inputs)
out["loss_single"] = float(m1["loss"])
out["loss_sharded"] = float(m2["loss"])
diffs = jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)))), p1, p2)
out["param_maxdiff"] = max(jax.tree.leaves(diffs))

# ---- shard_map + fused Pallas kernel TP-composability --------------------
from repro.core.quant import quantize
from repro.kernels import ref
from repro.kernels.w4a16_fused import w4a16_fused

K, N, M = 512, 256, 8
w = jax.random.normal(key, (K, N), jnp.float32)
x = jax.random.normal(key, (M, K), jnp.float32)
qt = quantize(w, group_size=64)

def per_shard(x, packed, scales):
    from repro.core.quant import QuantizedTensor
    q = QuantizedTensor(packed, scales, None, 64, jnp.dtype(jnp.float32))
    return w4a16_fused(x, q, interpret=True)

tp = shard_map(
    per_shard, mesh=mesh,
    in_specs=(P(None, None), P(None, "model"), P(None, "model")),
    out_specs=P(None, "model"), check_vma=False)
with set_mesh(mesh):
    y = tp(x, qt.packed, qt.scales)
want = ref.w4a16_ref(x, qt)
out["tp_w4a16_err"] = float(jnp.abs(y - want).max())
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_spmd_equivalence_and_tp_kernel():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert abs(out["loss_single"] - out["loss_sharded"]) < 1e-3, out
    assert out["param_maxdiff"] < 1e-2, out
    assert out["tp_w4a16_err"] < 1e-3, out
